//! GPU training function (paper Assumption 1, eq. 26, Fig. 2).
//!
//! GPUs execute in parallel: below a threshold batchsize `B_th` the gradient
//! latency is flat (`data bound` — the GPU is under-filled); above it the
//! latency grows linearly (`compute bound`):
//!
//! ```text
//! t^L(B) = t_l                     , 1 <= B <= B_th
//!        = c (B - B_th) + t_l      , B_th < B <= B_max
//! ```
//!
//! The paper validates this on three DNNs on a GTX 1080 Ti (Fig. 2b). We
//! ship (a) the analytic module used by the optimizer/simulator, (b) a
//! *measurement simulator* that produces noisy latency samples like the
//! paper's testbed, and (c) recovery of `(t_l, c, B_th)` from measurements
//! via `util::stats::fit_piecewise` — regenerating Fig. 2's model-vs-data
//! agreement is bench/experiment `fig2`.

use crate::util::rng::Pcg;
use crate::util::stats::{fit_piecewise, PiecewiseFit};

/// A GPU training module (eq. 26 coefficients + update cost eq. 27).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuModule {
    /// flat-region latency `t_l` (s)
    pub t_flat: f64,
    /// compute-bound slope `c` (s per sample)
    pub slope: f64,
    /// data/compute boundary `B_th`
    pub b_th: f64,
    /// FLOPs for one local model update (M^G)
    pub flops_per_update: f64,
    /// GPU throughput (FLOP/s), f^G
    pub flops_per_sec: f64,
}

impl GpuModule {
    pub fn new(t_flat: f64, slope: f64, b_th: f64, flops_per_update: f64, flops_per_sec: f64) -> Self {
        assert!(t_flat > 0.0 && slope >= 0.0 && b_th >= 1.0);
        assert!(flops_per_update >= 0.0 && flops_per_sec > 0.0);
        GpuModule { t_flat, slope, b_th, flops_per_update, flops_per_sec }
    }

    /// Local gradient calculation latency (eq. 26).
    pub fn grad_latency(&self, b: f64) -> f64 {
        if b <= self.b_th {
            self.t_flat
        } else {
            self.slope * (b - self.b_th) + self.t_flat
        }
    }

    /// Local model update latency (eq. 27).
    pub fn update_latency(&self) -> f64 {
        self.flops_per_update / self.flops_per_sec
    }

    /// Effective training speed in the compute-bound region: 1/slope
    /// (samples/s) — the GPU analogue of the CPU's `V_k` (Lemma 2 reduces
    /// the GPU problem to the CPU structure with this speed and constant
    /// offset `t_l - c*B_th`).
    pub fn compute_bound_speed(&self) -> f64 {
        if self.slope > 0.0 {
            1.0 / self.slope
        } else {
            f64::INFINITY
        }
    }

    /// Affine form of the compute-bound branch: `t(B) = B/speed + offset`.
    pub fn affine_offset(&self) -> f64 {
        self.t_flat - self.slope * self.b_th
    }

    /// Simulate a latency measurement at batchsize `b` with multiplicative
    /// noise (models the paper's Fig. 2(b) measurement scatter).
    pub fn measure(&self, b: f64, noise_frac: f64, rng: &mut Pcg) -> f64 {
        self.grad_latency(b) * (1.0 + noise_frac * rng.normal()).max(0.05)
    }

    /// Sweep batchsizes, produce measurements, and fit eq. 26 back.
    pub fn profile(&self, bs: &[f64], noise_frac: f64, rng: &mut Pcg) -> PiecewiseFit {
        let ts: Vec<f64> = bs.iter().map(|&b| self.measure(b, noise_frac, rng)).collect();
        fit_piecewise(bs, &ts)
    }
}

/// The three Fig. 2(b) profile shapes (DenseNet / GoogleNet / PNASNet on a
/// GTX 1080 Ti), rescaled to our mini models: same flat-then-linear shape,
/// knee, and relative ordering.
pub fn paper_profiles() -> Vec<(&'static str, GpuModule)> {
    vec![
        ("densenet", GpuModule::new(0.110, 2.4e-3, 24.0, 2.0e9, 1.0e13)),
        ("googlenet", GpuModule::new(0.075, 1.5e-3, 32.0, 1.3e9, 1.0e13)),
        ("pnasnet", GpuModule::new(0.210, 4.6e-3, 16.0, 3.2e9, 1.0e13)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_then_linear() {
        let g = GpuModule::new(0.1, 0.002, 32.0, 1e9, 1e13);
        assert_eq!(g.grad_latency(1.0), 0.1);
        assert_eq!(g.grad_latency(32.0), 0.1);
        assert!((g.grad_latency(64.0) - (0.1 + 0.002 * 32.0)).abs() < 1e-12);
    }

    #[test]
    fn continuity_at_knee() {
        let g = GpuModule::new(0.1, 0.002, 32.0, 1e9, 1e13);
        let eps = 1e-9;
        assert!((g.grad_latency(32.0 - eps) - g.grad_latency(32.0 + eps)).abs() < 1e-8);
    }

    #[test]
    fn monotone_nondecreasing() {
        let g = GpuModule::new(0.08, 0.0015, 24.0, 1e9, 1e13);
        let mut prev = 0.0;
        for b in 1..=128 {
            let t = g.grad_latency(b as f64);
            assert!(t >= prev);
            prev = t;
        }
    }

    #[test]
    fn profile_recovers_coefficients() {
        let mut rng = Pcg::seeded(5);
        for (name, g) in paper_profiles() {
            let bs: Vec<f64> = (1..=128).map(|b| b as f64).collect();
            let fit = g.profile(&bs, 0.02, &mut rng);
            assert!((fit.t_l - g.t_flat).abs() / g.t_flat < 0.1, "{name}: {fit:?}");
            assert!((fit.b_th - g.b_th).abs() <= 8.0, "{name}: {fit:?}");
            assert!((fit.c - g.slope).abs() / g.slope < 0.15, "{name}: {fit:?}");
        }
    }

    #[test]
    fn affine_reduction_consistent() {
        // compute-bound branch must equal B/speed + offset
        let g = GpuModule::new(0.1, 0.002, 32.0, 1e9, 1e13);
        for b in [33.0, 64.0, 128.0] {
            let affine = b / g.compute_bound_speed() + g.affine_offset();
            assert!((g.grad_latency(b) - affine).abs() < 1e-12);
        }
    }

    #[test]
    fn update_latency() {
        let g = GpuModule::new(0.1, 0.002, 32.0, 2e9, 1e13);
        assert!((g.update_latency() - 2e-4).abs() < 1e-15);
    }
}
