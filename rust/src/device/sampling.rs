//! Per-round participant sampling: which devices (or cells) take part in
//! a given training round.
//!
//! Real FEEL deployments never hear from the whole fleet every period —
//! participation is sampled (HierFAVG's two-level client/cell ratios,
//! arXiv 1905.06641; the partial-participation analysis in
//! arXiv 2005.05265). The sampler here draws each round's participant set
//! from a *counter-derived* stream (`Pcg::for_device`-style
//! `seed ^ TAG ⊕ period` keying), so the set for period `p` is a pure
//! function of `(seed, p, k)`: order-free across periods, identical at any
//! thread count, and computable without touching the other `K - |S|`
//! devices.
//!
//! Membership is i.i.d. Bernoulli(`frac`) per id. The draw walks the id
//! axis by geometric gaps (`gap = ⌊ln(1-u)/ln(1-frac)⌋`, the number of
//! exclusions before the next inclusion), so a round costs O(|sampled|)
//! draws — at K = 10⁶ and `frac = 1e-4` a round touches ~100 ids, never
//! a million. An empty draw promotes one uniform id instead (training
//! always needs a participant), still deterministic in `(seed, period)`.
//!
//! Unbiasedness: every id shares the inclusion probability `frac`, so the
//! Horvitz–Thompson correction is the uniform factor `1/frac` — it cancels
//! inside the self-normalized FedAvg mean (`grad::Aggregator::average`)
//! and surfaces only where an *absolute* scale matters: the trainer's
//! batch-driven step size and the cloud merge's per-cell weights.

use anyhow::{bail, Result};

use crate::util::rng::Pcg;

/// Stream tag for device-level (within-cell) participation draws.
const DEVICE_SAMPLE_TAG: u64 = 0x5e1e_c7ed_de71_ce5a;
/// Stream tag for cell-level (per cloud block) participation draws.
const CELL_SAMPLE_TAG: u64 = 0xce11_5e1e_c7ed_0b1c;

/// Draws one participant set per round from a counter-derived stream.
#[derive(Clone, Copy, Debug)]
pub struct ClientSampler {
    seed: u64,
    frac: f64,
}

impl ClientSampler {
    fn checked(seed: u64, frac: f64) -> Result<ClientSampler> {
        if !(frac > 0.0 && frac <= 1.0) {
            bail!("sampling fraction must be in (0, 1], got {frac}");
        }
        Ok(ClientSampler { seed, frac })
    }

    /// Device-level sampler: one participant set per training period.
    pub fn devices(seed: u64, frac: f64) -> Result<ClientSampler> {
        ClientSampler::checked(seed ^ DEVICE_SAMPLE_TAG, frac)
    }

    /// Cell-level sampler: one participant set per cloud block. Tagged on
    /// a separate stream so a topology sampling both levels never reuses
    /// draws between them.
    pub fn cells(seed: u64, frac: f64) -> Result<ClientSampler> {
        ClientSampler::checked(seed ^ CELL_SAMPLE_TAG, frac)
    }

    /// The configured inclusion probability.
    pub fn frac(&self) -> f64 {
        self.frac
    }

    /// The participant set for round `period` over ids `0..k`: strictly
    /// ascending, never empty for `k > 0`, O(|sampled|) work and memory.
    pub fn sample(&self, period: u64, k: usize) -> Vec<usize> {
        if k == 0 {
            return Vec::new();
        }
        if self.frac >= 1.0 {
            return (0..k).collect();
        }
        let mut rng = Pcg::for_device(self.seed, period, 0);
        // ln(1 - frac) is strictly negative for frac in (0, 1)
        let ln_q = (1.0 - self.frac).ln();
        let mut out = Vec::new();
        let mut next = 0usize;
        while next < k {
            // geometric gap: ids skipped before the next inclusion
            let gap = ((1.0 - rng.f64()).ln() / ln_q).floor();
            if !(gap < (k - next) as f64) {
                break;
            }
            next += gap as usize;
            out.push(next);
            next += 1;
        }
        if out.is_empty() {
            out.push(rng.below(k as u64) as usize);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::Aggregator;

    #[test]
    fn rejects_bad_fractions() {
        for frac in [0.0, -0.5, 1.5, f64::NAN, f64::INFINITY] {
            assert!(ClientSampler::devices(1, frac).is_err(), "frac {frac}");
            assert!(ClientSampler::cells(1, frac).is_err(), "frac {frac}");
        }
        assert!(ClientSampler::devices(1, 1.0).is_ok());
        assert!(ClientSampler::devices(1, 1e-9).is_ok());
    }

    #[test]
    fn full_fraction_selects_everyone() {
        let s = ClientSampler::devices(7, 1.0).unwrap();
        assert_eq!(s.sample(3, 5), vec![0, 1, 2, 3, 4]);
        assert!(s.sample(3, 0).is_empty());
    }

    #[test]
    fn sampled_ids_ascending_unique_in_range() {
        let s = ClientSampler::devices(42, 0.3).unwrap();
        for period in 0..50 {
            let ids = s.sample(period, 97);
            assert!(!ids.is_empty(), "period {period}");
            for w in ids.windows(2) {
                assert!(w[0] < w[1], "period {period}: {ids:?}");
            }
            assert!(*ids.last().unwrap() < 97, "period {period}");
        }
    }

    #[test]
    fn sets_are_deterministic_and_period_keyed() {
        let s = ClientSampler::devices(9, 0.2).unwrap();
        // replay: a pure function of (seed, period, k) — no hidden state,
        // so query order across periods cannot matter
        let early = s.sample(5, 200);
        for p in [0u64, 3, 11] {
            let _ = s.sample(p, 200);
        }
        assert_eq!(early, s.sample(5, 200));
        // different periods (and different seeds) decorrelate
        assert_ne!(s.sample(5, 200), s.sample(6, 200));
        let t = ClientSampler::devices(10, 0.2).unwrap();
        assert_ne!(s.sample(5, 200), t.sample(5, 200));
    }

    #[test]
    fn device_and_cell_streams_differ() {
        let d = ClientSampler::devices(3, 0.5).unwrap();
        let c = ClientSampler::cells(3, 0.5).unwrap();
        let differ = (0..20).filter(|&p| d.sample(p, 64) != c.sample(p, 64)).count();
        assert!(differ > 10, "only {differ} of 20 periods differ");
    }

    #[test]
    fn sample_size_tracks_fraction() {
        // mean |S| over many periods ≈ frac * k (Bernoulli thinning)
        for frac in [0.05, 0.3, 0.8] {
            let s = ClientSampler::devices(17, frac).unwrap();
            let rounds = 400u64;
            let total: usize = (0..rounds).map(|p| s.sample(p, 1000).len()).sum();
            let mean = total as f64 / rounds as f64;
            let expect = frac * 1000.0;
            // 4 sigma of the per-round binomial, averaged over `rounds`
            let tol = 4.0 * (expect * (1.0 - frac) / rounds as f64).sqrt();
            assert!((mean - expect).abs() < tol, "frac {frac}: mean {mean} vs {expect}");
        }
    }

    #[test]
    fn tiny_fraction_never_returns_empty() {
        let s = ClientSampler::devices(23, 1e-6).unwrap();
        for period in 0..200 {
            let ids = s.sample(period, 50);
            assert!(!ids.is_empty(), "period {period}");
            assert!(ids[0] < 50);
        }
    }

    #[test]
    fn large_k_cost_is_o_sampled() {
        // 1e6 ids at frac 1e-4: the draw returns ~100 ids; the only way
        // it finishes this fast deterministically is by skipping, but the
        // *checkable* contract is the output size and validity
        let s = ClientSampler::devices(31, 1e-4).unwrap();
        let ids = s.sample(7, 1_000_000);
        assert!(ids.len() > 40 && ids.len() < 220, "{}", ids.len());
        for w in ids.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn sampled_aggregate_is_unbiased_for_the_full_aggregate() {
        // K fixed per-device "gradients" with unequal batch weights. The
        // Horvitz–Thompson sum (weights scaled 1/frac) must match the
        // full-participation sum in expectation, and the self-normalized
        // FedAvg mean (the trainer's path — the 1/frac factors cancel)
        // must land on the full mean
        let k = 40usize;
        let dim = 6usize;
        let grads: Vec<Vec<f32>> = (0..k)
            .map(|i| (0..dim).map(|j| ((i * 7 + j * 3) % 13) as f32 - 6.0).collect())
            .collect();
        let weights: Vec<f64> = (0..k).map(|i| 8.0 + (i % 5) as f64).collect();
        let mut full = Aggregator::new(dim);
        for i in 0..k {
            full.add(&grads[i], weights[i]).unwrap();
        }
        let full_mean = full.average().unwrap();
        let w_total: f64 = weights.iter().sum();

        let frac = 0.25;
        let s = ClientSampler::devices(5, frac).unwrap();
        let rounds = 4000u64;
        let mut ht_sum = vec![0f64; dim];
        let mut mean_sum = vec![0f64; dim];
        let mut applied = 0u64;
        for p in 0..rounds {
            let ids = s.sample(p, k);
            let mut agg = Aggregator::new(dim);
            for &i in &ids {
                // inverse-inclusion-probability reweighting
                agg.add_inverse_prob(&grads[i], weights[i], frac).unwrap();
                for j in 0..dim {
                    ht_sum[j] += grads[i][j] as f64 * weights[i] / frac;
                }
            }
            let m = agg.average().unwrap();
            for j in 0..dim {
                mean_sum[j] += m[j] as f64;
            }
            applied += 1;
        }
        for j in 0..dim {
            // unbiased estimate of the weighted *sum*
            let est = ht_sum[j] / applied as f64;
            let want = full_mean[j] as f64 * w_total;
            assert!(
                (est - want).abs() < 0.05 * w_total.max(1.0),
                "dim {j}: HT {est} vs {want}"
            );
            // the trainer's self-normalized mean: 1/frac cancels, the
            // ratio estimator concentrates on the full mean
            let mean = mean_sum[j] / applied as f64;
            assert!(
                (mean - full_mean[j] as f64).abs() < 0.05,
                "dim {j}: mean {mean} vs {}",
                full_mean[j]
            );
        }
    }
}
