//! Cell topology: how a fleet of K devices, the system bandwidth, and the
//! global dataset are partitioned across C cells.
//!
//! Devices split into contiguous blocks (cell c owns global device ids
//! `[offset(c), offset(c) + size(c))`, first cells take the remainder),
//! so a cell's local device id `j` maps to global id `offset(c) + j` and
//! the paper's tier assignment (`id % 3`) keeps the same shape inside
//! every cell. Each cell runs its own base station on an even share of
//! the system band ([`CellConfig::split_bandwidth`] — the per-cell TDMA
//! budget) and owns its own slice of the dataset, split at the cell
//! level by the same `Partition` kind the devices use inside a cell —
//! `dirichlet:alpha` makes the per-cell skew controllable.
//!
//! Degenerate case (the compatibility contract `tests/exec_determinism.rs`
//! pins): C = 1 owns every device, the whole band (`x / 1.0` is exact),
//! and the dataset in natural order — no RNG is consumed — so a one-cell
//! hierarchy reproduces the flat `Trainer` bitwise.

use anyhow::{bail, Result};

use crate::data::partition::split_sizes;
use crate::data::{partition, Dataset, Partition};
use crate::util::rng::Pcg;
use crate::wireless::CellConfig;

/// Partition of the fleet, the band, and (via [`CellTopology::split_data`])
/// the dataset across C cells.
#[derive(Clone, Debug)]
pub struct CellTopology {
    sizes: Vec<usize>,
    offsets: Vec<usize>,
    configs: Vec<CellConfig>,
    tau: usize,
}

impl CellTopology {
    /// `k` devices over `cells` cells, cloud merges every `tau` edge
    /// rounds, each cell on an even share of `base`'s bandwidth.
    pub fn new(k: usize, cells: usize, tau: usize, base: CellConfig) -> Result<CellTopology> {
        if cells == 0 {
            bail!("topology needs at least one cell");
        }
        if tau == 0 {
            bail!("cloud cadence tau must be >= 1");
        }
        if k < cells {
            bail!("{cells} cells for {k} devices: every cell needs at least one device");
        }
        let sizes = split_sizes(k, cells);
        let mut offsets = Vec::with_capacity(cells);
        let mut off = 0usize;
        for &s in &sizes {
            offsets.push(off);
            off += s;
        }
        let configs = (0..cells).map(|_| base.split_bandwidth(cells)).collect();
        Ok(CellTopology { sizes, offsets, configs, tau })
    }

    /// Number of cells C.
    pub fn cells(&self) -> usize {
        self.sizes.len()
    }

    /// Total fleet size K.
    pub fn k(&self) -> usize {
        self.sizes.iter().sum()
    }

    /// Cloud aggregation cadence: edge rounds per cloud merge.
    pub fn tau(&self) -> usize {
        self.tau
    }

    /// Devices in cell `c`.
    pub fn size(&self, c: usize) -> usize {
        self.sizes[c]
    }

    /// Global device id of cell `c`'s first device.
    pub fn offset(&self, c: usize) -> usize {
        self.offsets[c]
    }

    /// The cell a global device id belongs to.
    pub fn cell_of(&self, device: usize) -> usize {
        assert!(device < self.k(), "device {device} outside the {}-device fleet", self.k());
        // contiguous blocks: the last offset at or below `device`
        self.offsets
            .iter()
            .rposition(|&off| off <= device)
            // lint: allow(panic-path): offsets[0] == 0 matches every device id
            .expect("offset 0 always matches")
    }

    /// Cell `c`'s wireless configuration (its TDMA bandwidth budget).
    pub fn config(&self, c: usize) -> CellConfig {
        self.configs[c]
    }

    /// Split the dataset across cells: per-cell sample indices into `ds`,
    /// by the same partition kinds devices use within a cell. One cell
    /// gets `0..len` in natural order without consuming the RNG — the
    /// flat-trainer degenerate case.
    pub fn split_data(&self, ds: &Dataset, kind: Partition, rng: &mut Pcg) -> Vec<Vec<usize>> {
        if self.cells() == 1 {
            return vec![(0..ds.len()).collect()];
        }
        partition(ds, self.cells(), kind, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SynthConfig};

    #[test]
    fn contiguous_cover_with_remainder_up_front() {
        let t = CellTopology::new(11, 3, 2, CellConfig::default()).unwrap();
        assert_eq!(t.cells(), 3);
        assert_eq!(t.k(), 11);
        assert_eq!(t.tau(), 2);
        assert_eq!((t.size(0), t.size(1), t.size(2)), (4, 4, 3));
        assert_eq!((t.offset(0), t.offset(1), t.offset(2)), (0, 4, 8));
        // cell_of is the inverse of the block layout
        for c in 0..t.cells() {
            for j in 0..t.size(c) {
                assert_eq!(t.cell_of(t.offset(c) + j), c, "cell {c} local {j}");
            }
        }
    }

    #[test]
    fn validation_rejects_bad_shapes() {
        let cc = CellConfig::default();
        assert!(CellTopology::new(4, 0, 1, cc).is_err());
        assert!(CellTopology::new(4, 1, 0, cc).is_err());
        assert!(CellTopology::new(2, 3, 1, cc).is_err());
        assert!(CellTopology::new(3, 3, 1, cc).is_ok());
    }

    #[test]
    #[should_panic]
    fn cell_of_out_of_range_panics() {
        let t = CellTopology::new(6, 2, 1, CellConfig::default()).unwrap();
        t.cell_of(6);
    }

    #[test]
    fn bandwidth_budget_split_evenly() {
        let base = CellConfig::default();
        let t = CellTopology::new(12, 4, 1, base).unwrap();
        for c in 0..4 {
            assert_eq!(t.config(c).bandwidth_hz, base.bandwidth_hz / 4.0);
        }
        // one cell keeps the whole band, bitwise
        let t1 = CellTopology::new(12, 1, 1, base).unwrap();
        assert_eq!(t1.config(0).bandwidth_hz.to_bits(), base.bandwidth_hz.to_bits());
    }

    #[test]
    fn split_data_single_cell_is_identity_order() {
        let ds = generate(&SynthConfig { dim: 8, ..Default::default() }, 120, 3);
        let t = CellTopology::new(6, 1, 1, CellConfig::default()).unwrap();
        let mut rng = Pcg::seeded(9);
        let before = rng.clone();
        let idx = t.split_data(&ds, Partition::Iid, &mut rng);
        assert_eq!(idx.len(), 1);
        assert_eq!(idx[0], (0..120).collect::<Vec<_>>());
        // no RNG consumed: the degenerate case cannot perturb anything
        let mut a = before;
        assert_eq!(a.next_u64(), rng.next_u64());
    }

    #[test]
    fn split_data_multi_cell_covers_disjointly() {
        let ds = generate(&SynthConfig { dim: 8, ..Default::default() }, 600, 3);
        let t = CellTopology::new(12, 3, 1, CellConfig::default()).unwrap();
        for kind in [
            Partition::Iid,
            Partition::NonIid,
            Partition::Dirichlet { alpha: 0.3 },
        ] {
            let mut rng = Pcg::seeded(4);
            let idx = t.split_data(&ds, kind, &mut rng);
            assert_eq!(idx.len(), 3, "{kind:?}");
            let mut all: Vec<usize> = idx.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..600).collect::<Vec<_>>(), "{kind:?}");
        }
    }
}
