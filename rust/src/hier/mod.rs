//! Hierarchical multi-cell FEEL: client → edge → cloud.
//!
//! The paper optimizes one cell; the production north star is many cells
//! — each with its own edge server, wireless budget, and scheduler —
//! feeding a cloud aggregator (Wang et al., arXiv:1804.05271 make the
//! edge→cloud cadence `tau` a first-class resource/accuracy knob; Qin et
//! al., arXiv:2005.05265 frame multi-cell coordination as *the* open
//! wireless-FL system problem). This subsystem is that scale seam:
//!
//! * [`CellTopology`] — partitions the fleet into C contiguous cells,
//!   each with an even TDMA bandwidth budget and its own data shard
//!   (cell-level `Partition`, so `dirichlet:alpha` controls per-cell
//!   skew);
//! * [`HierTrainer`] — one flat `Trainer` per cell (its own per-period
//!   batchsize/bandwidth optimization, round policy, straggler model,
//!   clock), run concurrently on the `exec::Engine` in blocks of `tau`
//!   edge rounds;
//! * [`CloudAggregator`] — sample-count-weighted FedAvg of the per-cell
//!   edge models at every block boundary, paired by model-family name so
//!   it composes with heterogeneous `BackendSet` fleets.
//!
//! Determinism contract: cells are independent between cloud rounds and
//! every cross-cell reduction (clock barrier, cloud merge, hierarchy
//! eval) runs in fixed cell order on the coordinator thread, so C-cell
//! runs are bitwise thread-invariant; the C = 1, tau = 1 degenerate case
//! reproduces the flat `Trainer` bitwise. Both are pinned by
//! `tests/exec_determinism.rs`.

pub mod cloud;
pub mod topology;
pub mod trainer;

pub use cloud::CloudAggregator;
pub use topology::CellTopology;
pub use trainer::{CellWorld, HierConfig, HierTrainer};
