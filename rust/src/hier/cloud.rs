//! Cloud-tier aggregation: FedAvg over the per-cell edge models.
//!
//! Every `tau` edge rounds (Wang et al., arXiv:1804.05271 — the
//! edge→cloud frequency is itself a resource/accuracy knob) the cloud
//! pulls each cell's per-family global parameters, averages them weighted
//! by the cell's training-sample count, and pushes the merged model back
//! to every member cell. Families pair up across cells **by model-family
//! name** — cells may have different tier mixes, so the same model can sit
//! at different family indices in different cells — and the merge walks
//! cells in fixed cell order with f64 accumulation (`grad::Aggregator`),
//! so a C-cell reduce is independent of which threads ran the cells.
//!
//! A family owned by a single cell stands untouched: FedAvg of one model
//! is that model, exactly — which also makes the C = 1 degenerate case a
//! bitwise no-op.

use anyhow::{bail, Result};

use crate::coordinator::Trainer;
use crate::grad::Aggregator;

/// Cloud-tier state: the merge cadence bookkeeping. The merged parameters
/// themselves live in the cells' servers — the cloud is a reducer, not a
/// third parameter store.
#[derive(Debug, Default)]
pub struct CloudAggregator {
    rounds: usize,
}

impl CloudAggregator {
    pub fn new() -> CloudAggregator {
        CloudAggregator::default()
    }

    /// Completed cloud rounds (merge calls).
    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// Restore the checkpointed cadence counter (the merged parameters
    /// themselves live in the cells' servers, which restore separately).
    pub fn restore_rounds(&mut self, rounds: usize) {
        self.rounds = rounds;
    }

    /// One cloud round: sample-count-weighted FedAvg of every model
    /// family shared by two or more cells, written back to all member
    /// cells. Returns how many families were actually merged (0 for a
    /// single cell or fully-disjoint families).
    pub fn merge(&mut self, cells: &mut [Trainer<'_>]) -> Result<usize> {
        self.rounds += 1;
        if cells.len() < 2 {
            return Ok(0);
        }
        // family names in first-cell, first-family order — a pure
        // function of the topology, never of execution order
        let mut names: Vec<String> = Vec::new();
        for tr in cells.iter() {
            let bs = tr.backend_set();
            for f in 0..bs.family_count() {
                let name = bs.family_name(f);
                if !names.iter().any(|n| n == name) {
                    names.push(name.to_string());
                }
            }
        }
        let mut merged = 0usize;
        for name in &names {
            // member (cell, family-index) pairs in fixed cell order
            let members: Vec<(usize, usize)> = cells
                .iter()
                .enumerate()
                .filter_map(|(c, tr)| {
                    let bs = tr.backend_set();
                    (0..bs.family_count())
                        .find(|&f| bs.family_name(f) == name)
                        .map(|f| (c, f))
                })
                .collect();
            if members.len() < 2 {
                // one owner: FedAvg of a single model is that model
                continue;
            }
            let (c0, f0) = members[0];
            let p = cells[c0].server.family_params(f0).len();
            let mut agg = Aggregator::new(p);
            for &(c, f) in &members {
                let params = cells[c].server.family_params(f);
                if params.len() != p {
                    bail!(
                        "cloud merge: family {name:?} has {} parameters in cell {c0} but {} \
                         in cell {c} — one family name must mean one model geometry",
                        p,
                        params.len()
                    );
                }
                agg.add(params, cells[c].total_samples() as f64)?;
            }
            let global = agg.finish()?;
            for &(c, f) in &members {
                cells[c].server.set_family_params(f, global.clone());
            }
            merged += 1;
        }
        Ok(merged)
    }

    /// A cloud round over a sampled cell subset: only `active` cells
    /// contribute, each weighted by `samples / frac` (Horvitz–Thompson —
    /// the uniform 1/frac cancels in the self-normalized average, but
    /// keeping it makes the estimator's unbiasedness explicit and the
    /// `frac == 1.0` case bitwise-identical to `merge`). The merged model
    /// is pushed back to **every** member cell, active or not, so the
    /// fleet leaves each cloud round consistent. A family whose owners
    /// were all unsampled this block stands untouched.
    pub fn merge_sampled(
        &mut self,
        cells: &mut [Trainer<'_>],
        active: &[bool],
        frac: f64,
    ) -> Result<usize> {
        let receive = vec![true; cells.len()];
        self.merge_guarded(cells, active, frac, &receive)
    }

    /// The general guarded cloud round: `contribute[c]` says whether cell
    /// c's edge model enters the average (sampled out or in outage =
    /// false), `receive[c]` whether the merged model is pushed back to
    /// it. A cell in outage neither contributes nor receives — it keeps
    /// its stale edge model and is merged back in, stale, when it
    /// rejoins. Contributors are weighted `samples / frac`
    /// (Horvitz–Thompson over the *sampling* draw; outage is not a
    /// sampling design, so pass `frac = 1.0` when only outage gates the
    /// round).
    pub fn merge_guarded(
        &mut self,
        cells: &mut [Trainer<'_>],
        contribute: &[bool],
        frac: f64,
        receive: &[bool],
    ) -> Result<usize> {
        if contribute.len() != cells.len() {
            bail!(
                "active mask covers {} cells but the fleet has {}",
                contribute.len(),
                cells.len()
            );
        }
        if receive.len() != cells.len() {
            bail!(
                "receive mask covers {} cells but the fleet has {}",
                receive.len(),
                cells.len()
            );
        }
        self.rounds += 1;
        if cells.len() < 2 {
            return Ok(0);
        }
        let mut names: Vec<String> = Vec::new();
        for tr in cells.iter() {
            let bs = tr.backend_set();
            for f in 0..bs.family_count() {
                let name = bs.family_name(f);
                if !names.iter().any(|n| n == name) {
                    names.push(name.to_string());
                }
            }
        }
        let mut merged = 0usize;
        for name in &names {
            let members: Vec<(usize, usize)> = cells
                .iter()
                .enumerate()
                .filter_map(|(c, tr)| {
                    let bs = tr.backend_set();
                    (0..bs.family_count())
                        .find(|&f| bs.family_name(f) == name)
                        .map(|f| (c, f))
                })
                .collect();
            if members.len() < 2 {
                continue;
            }
            let (c0, f0) = members[0];
            let p = cells[c0].server.family_params(f0).len();
            let mut agg = Aggregator::new(p);
            for &(c, f) in &members {
                let params = cells[c].server.family_params(f);
                if params.len() != p {
                    bail!(
                        "cloud merge: family {name:?} has {} parameters in cell {c0} but {} \
                         in cell {c} — one family name must mean one model geometry",
                        p,
                        params.len()
                    );
                }
                if contribute[c] {
                    agg.add_inverse_prob(params, cells[c].total_samples() as f64, frac)?;
                }
            }
            if agg.contributions() == 0 {
                // every owner sat this block out: the family stands
                continue;
            }
            let global = agg.finish()?;
            for &(c, f) in &members {
                if receive[c] {
                    cells[c].server.set_family_params(f, global.clone());
                }
            }
            merged += 1;
        }
        Ok(merged)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::HostBackend;
    use crate::coordinator::{Trainer, TrainerConfig};
    use crate::data::synthetic::{generate, SynthConfig};
    use crate::data::Partition;
    use crate::device::paper_cpu_fleet;
    use crate::util::rng::Pcg;
    use crate::wireless::CellConfig;

    fn cell_trainer<'a>(
        train: &'a crate::data::Dataset,
        test: &'a crate::data::Dataset,
        be: &'a HostBackend,
        k: usize,
        seed: u64,
    ) -> Trainer<'a> {
        let mut rng = Pcg::seeded(seed);
        let fleet = paper_cpu_fleet(k, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
        let cfg = TrainerConfig { seed, eval_every: 0, ..Default::default() };
        Trainer::new(cfg, fleet, train, test, Partition::Iid, be).unwrap()
    }

    fn named_cell_trainer<'a>(
        name: &str,
        be: &'a HostBackend,
        train: &'a crate::data::Dataset,
        test: &'a crate::data::Dataset,
        seed: u64,
    ) -> Trainer<'a> {
        let set = crate::coordinator::BackendSet::homogeneous(2, name, be);
        let mut rng = Pcg::seeded(seed);
        let fleet = paper_cpu_fleet(2, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
        let tc = TrainerConfig { seed, eval_every: 0, ..Default::default() };
        Trainer::with_backends(tc, fleet, train, test, Partition::Iid, set).unwrap()
    }

    #[test]
    fn merge_is_sample_weighted_fedavg() {
        let cfg = SynthConfig { dim: 8, ..Default::default() };
        // cell 0: 2 devices x 50 samples; cell 1: 2 devices x 100 samples
        let train_a = generate(&cfg, 100, 1);
        let train_b = generate(&cfg, 200, 1);
        let test = generate(&cfg, 40, 1);
        let be = HostBackend::for_model("mini_dense", 8, 10, 3).unwrap();
        let mut cells = vec![
            cell_trainer(&train_a, &test, &be, 2, 1),
            cell_trainer(&train_b, &test, &be, 2, 2),
        ];
        assert_eq!(cells[0].total_samples(), 100);
        assert_eq!(cells[1].total_samples(), 200);
        let p = cells[0].server.p();
        cells[0].server.set_family_params(0, vec![3.0; p]);
        cells[1].server.set_family_params(0, vec![6.0; p]);
        let mut cloud = CloudAggregator::new();
        let merged = cloud.merge(&mut cells).unwrap();
        assert_eq!(merged, 1);
        assert_eq!(cloud.rounds(), 1);
        // (3 * 100 + 6 * 200) / 300 = 5.0, pushed to both cells
        for tr in &cells {
            for &v in tr.server.params() {
                assert_eq!(v, 5.0);
            }
        }
    }

    #[test]
    fn sampled_merge_reweights_active_cells_and_pushes_to_all() {
        let cfg = SynthConfig { dim: 8, ..Default::default() };
        let train_a = generate(&cfg, 100, 1);
        let train_b = generate(&cfg, 200, 1);
        let test = generate(&cfg, 40, 1);
        let be = HostBackend::for_model("mini_dense", 8, 10, 3).unwrap();
        let mut cells = vec![
            cell_trainer(&train_a, &test, &be, 2, 1),
            cell_trainer(&train_b, &test, &be, 2, 2),
        ];
        let p = cells[0].server.p();
        let mut cloud = CloudAggregator::new();
        // both cells active: the uniform 1/frac cancels, so the result is
        // the plain sample-weighted FedAvg — (3*100 + 6*200)/300 = 5.0
        cells[0].server.set_family_params(0, vec![3.0; p]);
        cells[1].server.set_family_params(0, vec![6.0; p]);
        assert_eq!(cloud.merge_sampled(&mut cells, &[true, true], 0.5).unwrap(), 1);
        for tr in &cells {
            for &v in tr.server.params() {
                assert_eq!(v, 5.0);
            }
        }
        // only cell 1 active: its model IS the round's estimate, and the
        // push lands on the inactive cell too
        cells[0].server.set_family_params(0, vec![3.0; p]);
        cells[1].server.set_family_params(0, vec![6.0; p]);
        assert_eq!(cloud.merge_sampled(&mut cells, &[false, true], 0.5).unwrap(), 1);
        for tr in &cells {
            for &v in tr.server.params() {
                assert_eq!(v, 6.0);
            }
        }
        // no cell active: the family stands untouched
        cells[0].server.set_family_params(0, vec![3.0; p]);
        cells[1].server.set_family_params(0, vec![6.0; p]);
        assert_eq!(cloud.merge_sampled(&mut cells, &[false, false], 0.5).unwrap(), 0);
        assert_eq!(cells[0].server.params()[0], 3.0);
        assert_eq!(cells[1].server.params()[0], 6.0);
        // the mask must cover the fleet
        let err = cloud.merge_sampled(&mut cells, &[true], 0.5).unwrap_err().to_string();
        assert!(err.contains("active mask"), "{err}");
        assert_eq!(cloud.rounds(), 3);
    }

    #[test]
    fn single_cell_merge_is_a_noop() {
        let cfg = SynthConfig { dim: 8, ..Default::default() };
        let train = generate(&cfg, 100, 1);
        let test = generate(&cfg, 40, 1);
        let be = HostBackend::for_model("mini_dense", 8, 10, 3).unwrap();
        let mut cells = vec![cell_trainer(&train, &test, &be, 2, 1)];
        let before = cells[0].server.params().to_vec();
        let mut cloud = CloudAggregator::new();
        assert_eq!(cloud.merge(&mut cells).unwrap(), 0);
        assert_eq!(cells[0].server.params(), &before[..]);
        // the cadence counter still advances: a cloud round happened,
        // it just had nothing to consolidate
        assert_eq!(cloud.rounds(), 1);
    }

    #[test]
    fn disjoint_families_stand_and_shared_names_must_agree_on_geometry() {
        let cfg = SynthConfig { dim: 8, ..Default::default() };
        let train = generate(&cfg, 100, 1);
        let test = generate(&cfg, 40, 1);
        let dense = HostBackend::for_model("mini_dense", 8, 10, 3).unwrap();
        let res = HostBackend::for_model("mini_res", 8, 10, 3).unwrap();
        // cells on *different* (disjointly-named) model families: each
        // family has one owner, so nothing merges and both models stand
        let mut cells = vec![
            named_cell_trainer("mini_dense", &dense, &train, &test, 1),
            named_cell_trainer("mini_res", &res, &train, &test, 2),
        ];
        let before0 = cells[0].server.params().to_vec();
        let before1 = cells[1].server.params().to_vec();
        let mut cloud = CloudAggregator::new();
        assert_eq!(cloud.merge(&mut cells).unwrap(), 0);
        assert_eq!(cells[0].server.params(), &before0[..]);
        assert_eq!(cells[1].server.params(), &before1[..]);
        // same family name over different parameter geometries: the merge
        // must fail loudly, never average across parameter spaces
        let mut cells = vec![
            named_cell_trainer("shared", &dense, &train, &test, 1),
            named_cell_trainer("shared", &res, &train, &test, 2),
        ];
        assert_ne!(
            cells[0].server.p(),
            cells[1].server.p(),
            "test premise: the two mini models differ in parameter count"
        );
        let err = cloud.merge(&mut cells).unwrap_err().to_string();
        assert!(err.contains("one family name"), "{err}");
    }
}
