//! The hierarchical training loop: C concurrent cell trainers under one
//! cloud aggregator.
//!
//! Each cell is a full flat [`Trainer`] — its own fleet slice, dataset
//! shard, TDMA bandwidth budget, per-period batchsize/bandwidth
//! optimization (`opt/`), round policy (`sched/`), clock, and per-family
//! edge model. `HierTrainer` runs the cells **concurrently on the
//! existing `exec::Engine`** in blocks of `tau` edge rounds; at every
//! block boundary the cells barrier on the slowest cell's simulated
//! clock and the cloud FedAvg-merges their edge models (sample-count
//! weighted, per family name — see `hier::cloud`).
//!
//! Determinism: cells are fully independent between cloud rounds (their
//! RNG streams derive from per-cell seeds `base_seed ^ c * STRIDE`, and
//! each cell inherits the flat trainer's bitwise thread-invariance), and
//! every cross-cell reduction — the clock barrier's `max` fold and the
//! cloud merge — runs on the coordinator thread in fixed cell order. So a
//! C-cell run is bitwise thread-invariant, and the C = 1, tau = 1 case
//! reproduces the flat `Trainer` bitwise (`tests/exec_determinism.rs`
//! pins both).

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::cloud::CloudAggregator;
use crate::coordinator::checkpoint::{self, ByteReader, ByteWriter};
use crate::coordinator::{BackendSet, TrainLog, Trainer, TrainerConfig, WallStats};
use crate::data::{Dataset, Partition};
use crate::device::{ClientSampler, Device};
use crate::exec::Engine;
use crate::fault::FaultPlan;
use crate::obs::{self, ObsSink, Snap, TraceEvent};
use crate::sched::RoundPolicy;
use crate::util::rng::splitmix64;

/// Per-cell seed separation: cell c trains under seed
/// `base ^ (c * STRIDE)` (an odd multiplier, so distinct cells never
/// collide; cell 0 keeps the base seed exactly — the degenerate-case
/// anchor).
const CELL_SEED_STRIDE: u64 = 0x9e37_79b9_7f4a_7c15;

/// One cell's world: its device slice, backend registry, and data shard.
/// Built by hand in tests or by `exp::common::make_hier_world` from an
/// `Experiment`.
pub struct CellWorld<'a> {
    pub fleet: Vec<Device>,
    pub backends: BackendSet<'a>,
    pub train: &'a Dataset,
}

/// Hierarchy knobs on top of the per-cell [`TrainerConfig`].
#[derive(Clone, Debug)]
pub struct HierConfig {
    /// cloud cadence: edge rounds per cloud merge (>= 1)
    pub tau: usize,
    /// per-cell round-policy overrides, one per cell in cell order
    /// (empty = every cell closes rounds with the base config's policy)
    pub policies: Vec<RoundPolicy>,
    /// per-block cell sampling fraction in (0, 1]: each tau-block draws a
    /// Bernoulli(frac) subset of cells from a counter-derived stream (the
    /// block index is the period coordinate); only sampled cells run the
    /// block, and the cloud merge reweights them by the inverse inclusion
    /// probability. 1.0 = every cell every block — the legacy path,
    /// bitwise.
    pub cell_frac: f64,
}

impl Default for HierConfig {
    fn default() -> Self {
        HierConfig { tau: 1, policies: Vec::new(), cell_frac: 1.0 }
    }
}

/// C cell trainers plus the cloud tier above them.
pub struct HierTrainer<'a> {
    cells: Vec<Trainer<'a>>,
    /// outer fan-out: cells run concurrently, one engine item per cell
    engine: Engine,
    tau: usize,
    cloud: CloudAggregator,
    /// per-block cell sampler (`None` = every cell every block)
    sampler: Option<ClientSampler>,
    cell_frac: f64,
    /// completed tau-blocks — the cell sampler's period coordinate
    blocks: u64,
    /// hier-level fault plan: only `outage_rate` acts here (device-level
    /// crash/corruption lives inside each cell's scheduler)
    fault: FaultPlan,
    /// the un-offset base seed — the outage stream's key (cell trainers
    /// run under per-cell offset seeds; the outage draw uses the cell
    /// index as its stream coordinate instead)
    base_seed: u64,
    /// cloud-tier observability sink (trace lane C = one past the last
    /// cell; disabled by default). Cell-level events live in each cell
    /// trainer's own sink and are merged at export time.
    obs: ObsSink,
}

impl<'a> HierTrainer<'a> {
    /// Build the hierarchy: cell `c` trains under `base` with its seed
    /// offset by the cell id, its policy optionally overridden by
    /// `hc.policies[c]`, and an even share of the worker threads.
    pub fn new(
        base: TrainerConfig,
        hc: HierConfig,
        worlds: Vec<CellWorld<'a>>,
        test: &'a Dataset,
        kind: Partition,
    ) -> Result<HierTrainer<'a>> {
        if worlds.is_empty() {
            bail!("hierarchical trainer needs at least one cell");
        }
        if hc.tau == 0 {
            bail!("cloud cadence tau must be >= 1");
        }
        if !hc.policies.is_empty() && hc.policies.len() != worlds.len() {
            bail!(
                "{} per-cell policies for {} cells (give one per cell, or none)",
                hc.policies.len(),
                worlds.len()
            );
        }
        let sampler = if hc.cell_frac < 1.0 {
            if worlds.len() < 2 {
                bail!("cell_frac < 1.0 needs at least two cells to sample from");
            }
            Some(ClientSampler::cells(base.seed, hc.cell_frac)?)
        } else if hc.cell_frac == 1.0 {
            None
        } else {
            bail!("cell_frac must be in (0, 1], got {}", hc.cell_frac);
        };
        if base.fault.outage_active() && worlds.len() < 2 {
            bail!("cell outage injection (fault.outage_rate > 0) needs at least two cells");
        }
        let engine = Engine::new(base.threads);
        // split the thread budget across concurrent cells (wall-clock
        // only: numerics are thread-invariant at every level)
        let inner_threads = (engine.threads() / worlds.len()).max(1);
        let mut cells = Vec::with_capacity(worlds.len());
        for (c, w) in worlds.into_iter().enumerate() {
            let mut cfg = base.clone();
            cfg.seed = base.seed ^ (c as u64).wrapping_mul(CELL_SEED_STRIDE);
            if let Some(p) = hc.policies.get(c) {
                cfg.policy = *p;
            }
            cfg.threads = inner_threads;
            let mut tr = Trainer::with_backends(cfg, w.fleet, w.train, test, kind, w.backends)?;
            tr.set_cell_id(c);
            cells.push(tr);
        }
        Ok(HierTrainer {
            cells,
            engine,
            tau: hc.tau,
            cloud: CloudAggregator::new(),
            sampler,
            cell_frac: hc.cell_frac,
            blocks: 0,
            fault: base.fault,
            base_seed: base.seed,
            obs: ObsSink::disabled(),
        })
    }

    /// Number of cells C.
    pub fn cell_count(&self) -> usize {
        self.cells.len()
    }

    /// Cell `c`'s trainer (its log, server state, fleet).
    pub fn cell(&self, c: usize) -> &Trainer<'a> {
        &self.cells[c]
    }

    /// Cloud cadence (edge rounds per cloud merge).
    pub fn tau(&self) -> usize {
        self.tau
    }

    /// Completed cloud rounds.
    pub fn cloud_rounds(&self) -> usize {
        self.cloud.rounds()
    }

    /// Worker threads of the outer cell fan-out.
    pub fn threads(&self) -> usize {
        self.engine.threads()
    }

    /// Turn on structured tracing + metrics for the whole hierarchy:
    /// every cell's trainer records onto its own sink (trace process lane
    /// = cell id) and the cloud tier records onto lane C. Like the flat
    /// trainer's `enable_obs`, this consumes no RNG draws and changes no
    /// numerics.
    pub fn enable_obs(&mut self) {
        self.obs = ObsSink::enabled(self.cells.len());
        for tr in &mut self.cells {
            tr.enable_obs();
        }
    }

    /// Render the hierarchy-wide trace as Chrome trace-event JSON: cell
    /// events merged in fixed cell order (then stably sorted by
    /// timestamp), cloud events on the lane past the last cell.
    pub fn export_trace(&self) -> String {
        let mut parts: Vec<Vec<TraceEvent>> =
            self.cells.iter().map(|c| c.obs().events().to_vec()).collect();
        parts.push(self.obs.events().to_vec());
        let merged = obs::merge_traces(parts);
        obs::chrome_trace(&merged, Some(self.cells.len()))
    }

    /// Every cell's per-period metrics snapshots plus the cloud tier's
    /// per-block snapshots, as one JSONL stream ordered by (period, cell).
    pub fn export_metrics(&self) -> String {
        let mut parts: Vec<&[Snap]> = self.cells.iter().map(|c| c.obs().snaps()).collect();
        parts.push(self.obs.snaps());
        obs::merge_snaps(&parts)
    }

    /// Every cell's predicted-vs-realized audit ledger plus the cloud
    /// tier's merge rows, as one JSONL stream ordered by (period, cell) —
    /// cloud rows key their tau-block as the period coordinate, matching
    /// the cloud metrics snapshots.
    pub fn export_audit(&self) -> String {
        let mut parts: Vec<&obs::AuditLedger> =
            self.cells.iter().filter_map(|c| c.obs().audit()).collect();
        if let Some(led) = self.obs.audit() {
            parts.push(led);
        }
        obs::merge_audit(&parts)
    }

    /// The cloud tier's observability sink.
    pub fn obs(&self) -> &ObsSink {
        &self.obs
    }

    /// Simulated seconds: the slowest cell's clock (all cells agree right
    /// after a cloud barrier).
    pub fn sim_time(&self) -> f64 {
        self.cells.iter().map(|c| c.sim_time()).fold(0.0, f64::max)
    }

    /// Warm-start every cell's edge model (serial, fixed cell order).
    pub fn warm_start(&mut self, steps: usize, b: usize, lr: f32) -> Result<()> {
        for tr in &mut self.cells {
            tr.warm_start(steps, b, lr)?;
        }
        Ok(())
    }

    /// Run `periods` edge rounds per cell in blocks of `tau`: cells
    /// execute each block concurrently, then barrier on the slowest
    /// cell's clock and cloud-merge. A trailing partial block (periods
    /// not a multiple of tau) still ends with a merge, so every `run`
    /// leaves the system cloud-consistent.
    pub fn run(&mut self, periods: usize) -> Result<()> {
        let mut left = periods;
        while left > 0 {
            let block = left.min(self.tau);
            // cell sampling draws per tau-block from a counter-derived
            // stream: the block index is the period coordinate, so the
            // active set is a pure function of (seed, block) — order-free
            // and thread-invariant like everything else
            let active: Option<Vec<bool>> = self.sampler.map(|s| {
                let ids = s.sample(self.blocks, self.cells.len());
                let mut member = vec![false; self.cells.len()];
                ids.into_iter().for_each(|c| member[c] = true);
                member
            });
            // cell outage draws from its own tagged stream keyed on the
            // base seed with the cell index as the stream coordinate —
            // sampling and outage never perturb each other's draws, and
            // outage_rate = 0 skips the stream entirely (bitwise no-op)
            let up: Option<Vec<bool>> = if self.fault.outage_active() {
                Some(
                    (0..self.cells.len())
                        .map(|c| !self.fault.cell_out(self.base_seed, self.blocks, c as u64))
                        .collect(),
                )
            } else {
                None
            };
            // trace cell outages on the affected cell's own lane at its
            // current simulated time (the block it is about to sit out)
            if let Some(alive) = &up {
                for c in 0..self.cells.len() {
                    if !alive[c] {
                        let t = self.cells[c].sim_time();
                        self.cells[c].obs_mut().instant("cell_outage", "fault", 0, t);
                        self.obs.inc("fault.cell_outages", 1);
                    }
                }
            }
            // a cell runs the block iff it was sampled in AND its cell is
            // up; a None mask means "no gate of that kind this run"
            let ran: Option<Vec<bool>> = if active.is_none() && up.is_none() {
                None
            } else {
                Some(
                    (0..self.cells.len())
                        .map(|c| {
                            let sampled = match &active {
                                Some(m) => m[c],
                                None => true,
                            };
                            let alive = match &up {
                                Some(m) => m[c],
                                None => true,
                            };
                            sampled && alive
                        })
                        .collect(),
                )
            };
            self.blocks += 1;
            // one engine item per cell; each cell's own engine still fans
            // its device steps out on its scoped threads inside
            let member = ran.as_deref();
            self.engine.run_mut(&mut self.cells, |c, tr| {
                if member.is_some_and(|m| !m[c]) {
                    return Ok(()); // sat out this block: clock and log untouched
                }
                tr.run(block)?;
                Ok(())
            })?;
            self.cloud_round(ran.as_deref(), up.as_deref())?;
            left -= block;
        }
        Ok(())
    }

    /// One cloud synchronization point: barrier every cell's clock on the
    /// slowest cell (edge→cloud backhaul is priced at zero for now — the
    /// latency seam a later PR fills), then FedAvg the edge models. The
    /// cloud marker lands on the last record of the block; single-cell
    /// topologies skip both the barrier and the marker, keeping the
    /// degenerate case bitwise-flat. With cell sampling, only active
    /// cells contribute (inverse-probability reweighted) but the merged
    /// model is pushed to every member cell; inactive cells' clocks sat
    /// at the last barrier, so the max over all cells equals the max
    /// over active cells and the barrier needs no masking. A cell in
    /// *outage* is harsher than a sampled-out cell: it neither
    /// contributes nor receives — its edge model goes stale and is only
    /// folded back in after it rejoins. Its clock still barriers with
    /// everyone else (downtime is wall time, not a time warp).
    fn cloud_round(&mut self, ran: Option<&[bool]>, up: Option<&[bool]>) -> Result<()> {
        let t_cloud = self.cells.iter().map(|c| c.sim_time()).fold(0.0, f64::max);
        if self.cells.len() > 1 {
            for tr in &mut self.cells {
                tr.sync_clock_to(t_cloud);
            }
        }
        match (ran, up) {
            (None, _) => self.cloud.merge(&mut self.cells)?,
            (Some(mask), None) => {
                self.cloud.merge_sampled(&mut self.cells, mask, self.cell_frac)?
            }
            (Some(mask), Some(alive)) => {
                // reweight only for the sampling design; outage is a
                // fault, not an inclusion probability
                let frac = if self.sampler.is_some() { self.cell_frac } else { 1.0 };
                self.cloud.merge_guarded(&mut self.cells, mask, frac, alive)?
            }
        };
        if self.cells.len() > 1 {
            for (c, tr) in self.cells.iter_mut().enumerate() {
                if ran.is_some_and(|m| !m[c]) {
                    continue; // no record was produced this block
                }
                if let Some(r) = tr.log.records.last_mut() {
                    r.cloud = true;
                }
            }
        }
        // cloud-lane trace: one merge instant per tau-block at the
        // barrier time, plus a per-block metrics snapshot (`blocks` was
        // already bumped for this block, so snapshots are 1-based)
        if self.obs.is_enabled() {
            let merged = match ran {
                None => self.cells.len(),
                Some(mask) => mask.iter().filter(|&&m| m).count(),
            };
            self.obs.instant_arg(
                "cloud_merge",
                "cloud",
                0,
                t_cloud,
                &[("cells", merged as f64)],
            );
            self.obs.inc("cloud.merges", 1);
            self.obs.gauge("sim.time", t_cloud);
            self.obs.audit_cloud(self.blocks, t_cloud, merged);
            self.obs.snapshot(self.blocks);
        }
        Ok(())
    }

    /// Sample-count-weighted mean of the per-cell evaluations — right
    /// after a cloud round the shared families hold identical merged
    /// parameters, so this is the cloud model's test performance. Fixed
    /// cell order, f64 accumulation: deterministic like every other
    /// cross-cell reduction.
    pub fn evaluate(&mut self) -> Result<(f64, f64)> {
        let mut loss = 0f64;
        let mut acc = 0f64;
        let mut weight = 0f64;
        for tr in &mut self.cells {
            let w = tr.total_samples() as f64;
            let (l, a) = tr.evaluate()?;
            loss += l * w;
            acc += a * w;
            weight += w;
        }
        Ok((loss / weight, acc / weight))
    }

    /// One log over the whole hierarchy: every cell's records interleaved
    /// period-major (period 1 of every cell, then period 2, ...), each
    /// stamped with its cell id, wall stats summed. A one-cell hierarchy
    /// returns exactly its cell's log.
    pub fn merged_log(&self) -> TrainLog {
        let periods = self.cells.iter().map(|c| c.log.records.len()).max().unwrap_or(0);
        let mut records = Vec::with_capacity(periods * self.cells.len());
        for p in 0..periods {
            for tr in &self.cells {
                if let Some(r) = tr.log.records.get(p) {
                    records.push(*r);
                }
            }
        }
        let mut wall = WallStats::default();
        for tr in &self.cells {
            wall.solver_secs += tr.log.wall.solver_secs;
            wall.reduce_secs += tr.log.wall.reduce_secs;
            wall.total_secs += tr.log.wall.total_secs;
        }
        TrainLog { records, wall }
    }

    /// Digest of the hierarchy-level shape. Each nested cell payload
    /// carries its own full configuration digest, so this only needs the
    /// knobs that live above the cells.
    fn hier_digest(&self) -> u64 {
        let fields: [u64; 5] = [
            self.cells.len() as u64,
            self.tau as u64,
            self.cell_frac.to_bits(),
            self.fault.outage_rate.to_bits(),
            self.base_seed,
        ];
        fields.iter().fold(0x4e1e_7a11_c10d_5eed_u64, |h, &v| splitmix64(h ^ v))
    }

    fn checkpoint_payload(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u64(self.hier_digest());
        w.put_u64(self.blocks);
        w.put_usize(self.cloud.rounds());
        w.put_usize(self.cells.len());
        for tr in &self.cells {
            w.put_bytes(&tr.checkpoint_payload());
        }
        w.into_inner()
    }

    /// Write the full hierarchy state — every cell's flat-trainer payload
    /// plus the block and cloud-round counters — as one `KIND_HIER`
    /// checkpoint file.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        checkpoint::write_file(path, checkpoint::KIND_HIER, &self.checkpoint_payload())
    }

    /// Restore a hierarchy from [`save_checkpoint`](Self::save_checkpoint)
    /// output. All-or-nothing like the flat resume: every cell payload is
    /// framed and digest-checked, and if any cell fails to restore, the
    /// cells already touched are rolled back to their pre-call state.
    pub fn resume_from(&mut self, path: &Path) -> Result<()> {
        let payload = checkpoint::read_file(path, checkpoint::KIND_HIER)?;
        self.restore_payload(&payload)
            .with_context(|| format!("restoring checkpoint {}", path.display()))?;
        let t = self.sim_time();
        self.obs.instant("ckpt_restore", "ckpt", 0, t);
        self.obs.instant("run.resumed", "ckpt", 0, t);
        self.obs.inc("ckpt.restores", 1);
        self.obs.gauge("ckpt.resume_period", self.blocks as f64);
        Ok(())
    }

    fn restore_payload(&mut self, payload: &[u8]) -> Result<()> {
        let mut r = ByteReader::new(payload);
        let digest = r.get_u64()?;
        if digest != self.hier_digest() {
            bail!(
                "checkpoint was written by a differently-shaped hierarchy (cell count, tau, \
                 cell_frac, outage rate, and seed must all match)"
            );
        }
        let blocks = r.get_u64()?;
        let rounds = r.get_usize()?;
        let n = r.get_usize()?;
        if n != self.cells.len() {
            bail!("checkpoint holds {n} cells, this hierarchy has {}", self.cells.len());
        }
        let mut cell_payloads = Vec::with_capacity(n);
        for _ in 0..n {
            cell_payloads.push(r.get_bytes()?);
        }
        r.expect_end()?;
        // capture each cell's live state first so a failure deep in one
        // cell's payload can roll the earlier cells back — resume stays
        // all-or-nothing across the whole hierarchy
        let before: Vec<Vec<u8>> = self.cells.iter().map(Trainer::checkpoint_payload).collect();
        for (c, bytes) in cell_payloads.iter().enumerate() {
            if let Err(e) = self.cells[c].restore_payload(bytes) {
                for (u, saved) in before.iter().enumerate().take(c) {
                    // the rollback payload came from this very trainer a
                    // moment ago, so it cannot fail to parse
                    let _ = self.cells[u].restore_payload(saved);
                }
                return Err(e).with_context(|| format!("cell {c}"));
            }
        }
        self.blocks = blocks;
        self.cloud.restore_rounds(rounds);
        Ok(())
    }

    /// [`run`](Self::run), saving a checkpoint every `every` tau-blocks
    /// (the hierarchy's natural consistency points — mid-block there is
    /// un-merged cell state). `every = 0` never saves. The cadence is
    /// keyed on the global block counter, so a resumed run checkpoints on
    /// the same schedule as an uninterrupted one.
    pub fn run_checkpointed(&mut self, periods: usize, every: usize, path: &Path) -> Result<()> {
        let mut left = periods;
        while left > 0 {
            let block = left.min(self.tau);
            self.run(block)?;
            left -= block;
            if every > 0 && self.blocks % every as u64 == 0 {
                self.save_checkpoint(path)?;
                let t = self.sim_time();
                self.obs.instant("ckpt_save", "ckpt", 0, t);
                self.obs.inc("ckpt.saves", 1);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::HostBackend;
    use crate::data::synthetic::{generate, SynthConfig};
    use crate::device::paper_cpu_fleet;
    use crate::util::rng::Pcg;
    use crate::wireless::CellConfig;

    const DIM: usize = 12;

    fn world<'a>(train: &'a Dataset, be: &'a HostBackend, k: usize, seed: u64) -> CellWorld<'a> {
        let mut rng = Pcg::seeded(seed);
        let cell = CellConfig::default().split_bandwidth(2);
        CellWorld {
            fleet: paper_cpu_fleet(k, 7e7, 1e8, cell, 4.0, 0.5, &mut rng),
            backends: BackendSet::homogeneous(k, "mini_res", be),
            train,
        }
    }

    fn two_cell_setup() -> (Dataset, Dataset, Dataset, HostBackend) {
        let cfg = SynthConfig { dim: DIM, ..Default::default() };
        let a = generate(&cfg, 160, 1);
        let b = generate(&cfg, 240, 2);
        let test = generate(&cfg, 80, 3);
        let be = HostBackend::for_model("mini_res", DIM, 10, 3).unwrap();
        (a, b, test, be)
    }

    #[test]
    fn two_cells_learn_and_share_the_merged_model() {
        let (a, b, test, be) = two_cell_setup();
        let worlds = vec![world(&a, &be, 2, 10), world(&b, &be, 2, 11)];
        let base = TrainerConfig { eval_every: 0, ..Default::default() };
        let hc = HierConfig { tau: 2, ..Default::default() };
        let mut hier = HierTrainer::new(base, hc, worlds, &test, Partition::Iid).unwrap();
        assert_eq!(hier.cell_count(), 2);
        hier.run(6).unwrap();
        // 6 periods / tau 2 -> 3 cloud rounds
        assert_eq!(hier.cloud_rounds(), 3);
        // after the final merge both cells hold the same edge model
        assert_eq!(hier.cell(0).server.params(), hier.cell(1).server.params());
        // and the barrier left both clocks on the cloud's time axis
        assert_eq!(hier.cell(0).sim_time().to_bits(), hier.cell(1).sim_time().to_bits());
        // the hierarchy learns
        let log = hier.merged_log();
        assert_eq!(log.records.len(), 12);
        let first = log.records[0].train_loss + log.records[1].train_loss;
        let last = log.records[10].train_loss + log.records[11].train_loss;
        assert!(last < first, "loss {first} -> {last}");
        // eval is sane
        let (loss, acc) = hier.evaluate().unwrap();
        assert!(loss.is_finite());
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn merged_log_interleaves_cells_and_marks_cloud_rounds() {
        let (a, b, test, be) = two_cell_setup();
        let worlds = vec![world(&a, &be, 2, 10), world(&b, &be, 2, 11)];
        let base = TrainerConfig { eval_every: 0, ..Default::default() };
        let hc = HierConfig { tau: 2, ..Default::default() };
        let mut hier = HierTrainer::new(base, hc, worlds, &test, Partition::Iid).unwrap();
        hier.run(5).unwrap(); // blocks of 2, 2, 1 -> merges after 2, 4, 5
        let log = hier.merged_log();
        assert_eq!(log.records.len(), 10);
        for (i, r) in log.records.iter().enumerate() {
            assert_eq!(r.cell, i % 2, "record {i}");
            assert_eq!(r.period, i / 2 + 1, "record {i}");
            let marked = matches!(r.period, 2 | 4 | 5);
            assert_eq!(r.cloud, marked, "record {i} (period {})", r.period);
        }
        // per-cell sim_time is monotone even across cloud barriers
        for c in 0..2 {
            let times: Vec<f64> =
                log.records.iter().filter(|r| r.cell == c).map(|r| r.sim_time).collect();
            for w in times.windows(2) {
                assert!(w[1] > w[0], "cell {c}: {} -> {}", w[0], w[1]);
            }
        }
        // the CSV carries the new columns through
        let csv = log.to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 11);
        assert!(lines[1].ends_with(",0,0,0,0,0"), "{}", lines[1]);
        assert!(lines[2].ends_with(",1,0,0,0,0"), "{}", lines[2]);
        assert!(lines[3].ends_with(",0,1,0,0,0"), "{}", lines[3]);
        assert!(lines[4].ends_with(",1,1,0,0,0"), "{}", lines[4]);
    }

    #[test]
    fn per_cell_policies_apply_and_validate() {
        let (a, b, test, be) = two_cell_setup();
        // wrong policy count is rejected
        let worlds = vec![world(&a, &be, 2, 10), world(&b, &be, 2, 11)];
        let base = TrainerConfig { eval_every: 0, ..Default::default() };
        let hc = HierConfig { policies: vec![RoundPolicy::Sync], ..Default::default() };
        let err = HierTrainer::new(base.clone(), hc, worlds, &test, Partition::Iid)
            .err()
            .unwrap()
            .to_string();
        assert!(err.contains("per-cell policies"), "{err}");
        // tau 0 is rejected
        let worlds = vec![world(&a, &be, 2, 10)];
        let hc = HierConfig { tau: 0, ..Default::default() };
        assert!(HierTrainer::new(base.clone(), hc, worlds, &test, Partition::Iid).is_err());
        // no cells is rejected
        let hc = HierConfig::default();
        assert!(HierTrainer::new(base.clone(), hc, Vec::new(), &test, Partition::Iid).is_err());
        // a mixed-policy hierarchy runs: cell 0 sync, cell 1 deadline
        let worlds = vec![world(&a, &be, 2, 10), world(&b, &be, 2, 11)];
        let hc = HierConfig {
            tau: 2,
            policies: vec![RoundPolicy::Sync, RoundPolicy::Deadline { factor: 1.5 }],
            ..Default::default()
        };
        let mut hier = HierTrainer::new(base, hc, worlds, &test, Partition::Iid).unwrap();
        assert_eq!(hier.cell(0).policy(), RoundPolicy::Sync);
        assert_eq!(hier.cell(1).policy(), RoundPolicy::Deadline { factor: 1.5 });
        hier.run(2).unwrap();
        assert_eq!(hier.merged_log().records.len(), 4);
    }

    #[test]
    fn cell_sampling_runs_subsets_and_stays_cloud_consistent() {
        let (a, b, test, be) = two_cell_setup();
        // cell_frac out of range is rejected
        let worlds = vec![world(&a, &be, 2, 10), world(&b, &be, 2, 11)];
        let base = TrainerConfig { eval_every: 0, ..Default::default() };
        let hc = HierConfig { cell_frac: 0.0, ..Default::default() };
        assert!(HierTrainer::new(base.clone(), hc, worlds, &test, Partition::Iid).is_err());
        // sampling a single-cell topology is a config error, not a no-op
        let worlds = vec![world(&a, &be, 2, 10)];
        let hc = HierConfig { cell_frac: 0.5, ..Default::default() };
        let err = HierTrainer::new(base.clone(), hc, worlds, &test, Partition::Iid)
            .err()
            .unwrap()
            .to_string();
        assert!(err.contains("at least two cells"), "{err}");
        // a sampled two-cell hierarchy runs: some blocks skip a cell, so
        // the per-cell logs go ragged, but every merge still leaves the
        // shared family identical across cells
        let worlds = vec![world(&a, &be, 2, 10), world(&b, &be, 2, 11)];
        let hc = HierConfig { tau: 1, cell_frac: 0.5, ..Default::default() };
        let mut hier = HierTrainer::new(base, hc, worlds, &test, Partition::Iid).unwrap();
        hier.run(8).unwrap();
        assert_eq!(hier.cloud_rounds(), 8);
        assert_eq!(hier.cell(0).server.params(), hier.cell(1).server.params());
        let n0 = hier.cell(0).log.records.len();
        let n1 = hier.cell(1).log.records.len();
        assert!(n0 <= 8 && n1 <= 8);
        assert!(n0 + n1 > 0, "sampler never picked any cell in 8 blocks");
        assert!(n0 < 8 || n1 < 8, "frac 0.5 never skipped a cell in 8 blocks");
        // the merged log stays coherent with ragged per-cell records
        let log = hier.merged_log();
        assert_eq!(log.records.len(), n0 + n1);
        // eval after the final merge is sane
        let (loss, acc) = hier.evaluate().unwrap();
        assert!(loss.is_finite());
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn cell_outage_skips_blocks_and_keeps_clocks_barriered() {
        use crate::fault::FaultPlan;
        let (a, b, test, be) = two_cell_setup();
        // outage on a single-cell topology is a config error, not a no-op
        let worlds = vec![world(&a, &be, 2, 10)];
        let base = TrainerConfig {
            eval_every: 0,
            fault: FaultPlan::new(0.0, 1, 0.0, 0.0, 0.5).unwrap(),
            ..Default::default()
        };
        let err =
            HierTrainer::new(base.clone(), HierConfig::default(), worlds, &test, Partition::Iid)
                .err()
                .unwrap()
                .to_string();
        assert!(err.contains("at least two cells"), "{err}");
        // with two cells and a heavy outage rate, some tau-blocks lose a
        // cell: its log goes ragged but the run stays cloud-consistent
        let worlds = vec![world(&a, &be, 2, 10), world(&b, &be, 2, 11)];
        let hc = HierConfig { tau: 1, ..Default::default() };
        let mut hier = HierTrainer::new(base, hc, worlds, &test, Partition::Iid).unwrap();
        hier.run(8).unwrap();
        assert_eq!(hier.cloud_rounds(), 8);
        let n0 = hier.cell(0).log.records.len();
        let n1 = hier.cell(1).log.records.len();
        assert!(n0 + n1 < 16, "outage rate 0.5 never took a cell down in 8 blocks");
        assert!(n0 + n1 > 0, "outage rate 0.5 took every cell down in every block");
        // outage is wall time, not a time warp: the barrier still syncs
        // every cell's clock, down or not
        assert_eq!(hier.cell(0).sim_time().to_bits(), hier.cell(1).sim_time().to_bits());
        let (loss, acc) = hier.evaluate().unwrap();
        assert!(loss.is_finite());
        assert!((0.0..=1.0).contains(&acc));
        // zero-rate outage constructs fine and gates nothing
        let worlds = vec![world(&a, &be, 2, 10), world(&b, &be, 2, 11)];
        let base = TrainerConfig {
            eval_every: 0,
            fault: FaultPlan::new(0.0, 1, 0.0, 0.0, 0.0).unwrap(),
            ..Default::default()
        };
        let hc = HierConfig { tau: 1, ..Default::default() };
        let mut quiet = HierTrainer::new(base, hc, worlds, &test, Partition::Iid).unwrap();
        quiet.run(3).unwrap();
        assert_eq!(quiet.cell(0).log.records.len(), 3);
        assert_eq!(quiet.cell(1).log.records.len(), 3);
    }

    #[test]
    fn hier_checkpoint_roundtrips_and_rejects_mismatched_shape() {
        let (a, b, test, be) = two_cell_setup();
        let path = std::env::temp_dir().join(format!("feel_hier_ckpt_{}", std::process::id()));
        let base = TrainerConfig { eval_every: 0, ..Default::default() };
        let hc = HierConfig { tau: 2, ..Default::default() };
        let make =
            |worlds| HierTrainer::new(base.clone(), hc.clone(), worlds, &test, Partition::Iid);
        // run 4 periods, checkpoint, run 4 more: the reference trace
        let worlds = vec![world(&a, &be, 2, 10), world(&b, &be, 2, 11)];
        let mut full = make(worlds).unwrap();
        full.run(4).unwrap();
        full.save_checkpoint(&path).unwrap();
        full.run(4).unwrap();
        // a fresh hierarchy resumed from the checkpoint must continue
        // bitwise-identically
        let worlds = vec![world(&a, &be, 2, 10), world(&b, &be, 2, 11)];
        let mut resumed = make(worlds).unwrap();
        resumed.resume_from(&path).unwrap();
        resumed.run(4).unwrap();
        assert_eq!(full.cloud_rounds(), resumed.cloud_rounds());
        assert_eq!(full.blocks, resumed.blocks);
        for c in 0..2 {
            assert_eq!(full.cell(c).server.params(), resumed.cell(c).server.params(), "cell {c}");
            assert_eq!(
                full.cell(c).sim_time().to_bits(),
                resumed.cell(c).sim_time().to_bits(),
                "cell {c}"
            );
        }
        assert_eq!(full.merged_log().to_csv(), resumed.merged_log().to_csv());
        // a differently-shaped hierarchy refuses the file
        let worlds = vec![world(&a, &be, 2, 10), world(&b, &be, 2, 11)];
        let hc3 = HierConfig { tau: 3, ..Default::default() };
        let mut other =
            HierTrainer::new(base.clone(), hc3, worlds, &test, Partition::Iid).unwrap();
        let err = other.resume_from(&path).unwrap_err().to_string();
        assert!(err.contains("differently-shaped"), "{err}");
        // and a flat trainer refuses the hier kind byte outright
        let payload = checkpoint::read_file(&path, checkpoint::KIND_HIER).unwrap();
        assert!(!payload.is_empty());
        assert!(checkpoint::read_file(&path, checkpoint::KIND_FLAT).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn warm_start_warms_every_cell() {
        let (a, b, test, be) = two_cell_setup();
        let worlds = vec![world(&a, &be, 2, 10), world(&b, &be, 2, 11)];
        let base = TrainerConfig { eval_every: 0, ..Default::default() };
        let mut hier = HierTrainer::new(base, HierConfig::default(), worlds, &test, Partition::Iid)
            .unwrap();
        let (cold, _) = hier.evaluate().unwrap();
        hier.warm_start(40, 32, 0.05).unwrap();
        let (warm, _) = hier.evaluate().unwrap();
        assert!(warm < cold, "{cold} -> {warm}");
    }
}
