//! Metrics: learning-efficiency accounting and results recording.

pub mod recorder;

pub use recorder::Recorder;

/// Training speedup of `scheme_time` relative to `baseline_time` for
/// reaching the same loss target (Table II's metric): higher is faster.
pub fn speedup(baseline_time: f64, scheme_time: f64) -> f64 {
    assert!(baseline_time > 0.0 && scheme_time > 0.0);
    baseline_time / scheme_time
}

#[cfg(test)]
mod tests {
    #[test]
    fn speedup_ratio() {
        assert_eq!(super::speedup(10.0, 5.0), 2.0);
        assert_eq!(super::speedup(5.0, 10.0), 0.5);
    }
}
