//! Metrics: learning-efficiency accounting and results recording.

pub mod recorder;

pub use recorder::Recorder;

/// Training speedup of `scheme_time` relative to `baseline_time` for
/// reaching the same loss target (Table II's metric): higher is faster.
/// Non-positive (or NaN) times are a structured error, not a panic — a
/// scheme that never reached the target reports a time of 0 upstream of
/// some callers, and that should surface as a diagnosable message.
pub fn speedup(baseline_time: f64, scheme_time: f64) -> anyhow::Result<f64> {
    let bad = |t: f64| t.is_nan() || t <= 0.0;
    if bad(baseline_time) || bad(scheme_time) {
        anyhow::bail!(
            "speedup needs positive times, got baseline {baseline_time} vs scheme {scheme_time}"
        );
    }
    Ok(baseline_time / scheme_time)
}

#[cfg(test)]
mod tests {
    #[test]
    fn speedup_ratio() {
        assert_eq!(super::speedup(10.0, 5.0).unwrap(), 2.0);
        assert_eq!(super::speedup(5.0, 10.0).unwrap(), 0.5);
    }

    #[test]
    fn speedup_rejects_non_positive_times() {
        for (b, t) in [(0.0, 1.0), (1.0, 0.0), (-1.0, 1.0), (f64::NAN, 1.0)] {
            let err = super::speedup(b, t).unwrap_err().to_string();
            assert!(err.contains("positive times"), "{err}");
        }
    }
}
