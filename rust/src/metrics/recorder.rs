//! Results recorder: writes experiment outputs (CSV series + a JSON
//! summary) under a results directory so every table/figure regeneration
//! leaves an auditable artifact.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Writes experiment outputs under `<root>/<experiment>/`.
pub struct Recorder {
    dir: PathBuf,
}

impl Recorder {
    pub fn new(root: &Path, experiment: &str) -> Result<Recorder> {
        let dir = root.join(experiment);
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        Ok(Recorder { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Write a CSV file (caller supplies full text including header).
    pub fn csv(&self, name: &str, content: &str) -> Result<PathBuf> {
        let path = self.dir.join(format!("{name}.csv"));
        let mut f = std::fs::File::create(&path)?;
        f.write_all(content.as_bytes())?;
        Ok(path)
    }

    /// Write a JSON summary.
    pub fn json(&self, name: &str, value: &Json) -> Result<PathBuf> {
        let path = self.dir.join(format!("{name}.json"));
        std::fs::write(&path, value.to_string())?;
        Ok(path)
    }

    /// Append a line to the experiment's log.
    pub fn log(&self, line: &str) -> Result<()> {
        let path = self.dir.join("run.log");
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        writeln!(f, "{line}")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{num, obj};

    #[test]
    fn writes_all_kinds() {
        let tmp = std::env::temp_dir().join(format!("feel_rec_{}", std::process::id()));
        let r = Recorder::new(&tmp, "unit").unwrap();
        let p = r.csv("series", "a,b\n1,2\n").unwrap();
        assert!(p.exists());
        let j = r.json("summary", &obj(vec![("x", num(1.0))])).unwrap();
        assert!(std::fs::read_to_string(j).unwrap().contains("\"x\""));
        r.log("hello").unwrap();
        r.log("world").unwrap();
        let log = std::fs::read_to_string(r.dir().join("run.log")).unwrap();
        assert_eq!(log, "hello\nworld\n");
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn metrics_snapshot_round_trip() {
        // record → snapshot → serialize through the Recorder → parse back:
        // the values that went in come back out.
        let mut m = crate::obs::MetricsRegistry::default();
        m.inc("round.applied", 4);
        m.gauge("train.loss", 0.5);
        m.observe("round.duration", 1.25);
        m.snapshot(3, 0);

        let tmp = std::env::temp_dir().join(format!("feel_rec_rt_{}", std::process::id()));
        let r = Recorder::new(&tmp, "unit").unwrap();
        let path = r.dir().join("metrics.jsonl");
        std::fs::write(&path, m.to_jsonl()).unwrap();

        let text = std::fs::read_to_string(&path).unwrap();
        let line = text.lines().next().unwrap();
        let v = Json::parse(line).unwrap();
        assert_eq!(v.get("period").unwrap().as_usize(), Some(3));
        assert_eq!(
            v.get("counters").unwrap().get("round.applied").unwrap().as_f64(),
            Some(4.0)
        );
        assert_eq!(
            v.get("gauges").unwrap().get("train.loss").unwrap().as_f64(),
            Some(0.5)
        );
        let h = v.get("hists").unwrap().get("round.duration").unwrap();
        assert_eq!(h.get("total").unwrap().as_f64(), Some(1.0));
        assert_eq!(h.get("sum").unwrap().as_f64(), Some(1.25));
        std::fs::remove_dir_all(&tmp).ok();
    }
}
