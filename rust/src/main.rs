//! `feel` CLI — leader entrypoint (see cli.rs for the subcommands).

fn main() -> anyhow::Result<()> {
    feel::cli::main()
}
