//! Brute-force reference optimizer: exhaustive grid over per-device batch
//! vectors with exact optimal slot allocation per vector (bisection). Used
//! to validate Algorithm-1 optimality (tests) and to cost the paper's
//! complexity claim (bench_ablation). Exponential in K — keep K and the
//! grid resolution small.

use anyhow::{Context, Result};

use super::downlink::solve_downlink;
use super::types::{Instance, Solution};
use super::uplink::makespan_for_batches;

/// Result of a grid search.
#[derive(Clone, Debug)]
pub struct GridSol {
    pub solution: Solution,
    pub efficiency: f64,
    pub evals: usize,
}

/// Exhaustively search batch vectors with each B_k on an `n_steps`-point
/// grid over [b_min, b_max], maximizing the learning efficiency.
pub fn grid_search(inst: &Instance, n_steps: usize, eps: f64) -> Result<GridSol> {
    assert!(n_steps >= 2);
    let dl = solve_downlink(inst, eps)?;
    let k = inst.k();
    let grids: Vec<Vec<f64>> = inst
        .devices
        .iter()
        .map(|d| {
            (0..n_steps)
                .map(|i| d.b_min + (d.b_max - d.b_min) * i as f64 / (n_steps - 1) as f64)
                .collect()
        })
        .collect();
    let mut idx = vec![0usize; k];
    let mut best: Option<(f64, Vec<f64>, f64, Vec<f64>)> = None;
    let mut evals = 0usize;
    loop {
        let batches: Vec<f64> = idx.iter().zip(&grids).map(|(&i, g)| g[i]).collect();
        evals += 1;
        if let Ok((t_up, tau)) = makespan_for_batches(inst, &batches) {
            let b_total: f64 = batches.iter().sum();
            let eff = inst.loss_decay(b_total) / (t_up + dl.t_down);
            if best.as_ref().map_or(true, |(e, ..)| eff > *e) {
                best = Some((eff, batches, t_up, tau));
            }
        }
        // odometer increment
        let mut pos = 0;
        loop {
            if pos == k {
                let (eff, batches, t_up, tau) =
                    best.context("grid search found no feasible batch vector")?;
                let b_total = batches.iter().sum();
                return Ok(GridSol {
                    solution: Solution {
                        batches,
                        tau_ul: tau,
                        tau_dl: dl.tau,
                        t_up,
                        t_down: dl.t_down,
                        b_total,
                    },
                    efficiency: eff,
                    evals,
                });
            }
            idx[pos] += 1;
            if idx[pos] < n_steps {
                break;
            }
            idx[pos] = 0;
            pos += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::global::solve;
    use crate::opt::types::test_instance;

    #[test]
    fn algorithm1_at_least_as_good_as_grid() {
        // closed form + bisection should match (or beat) a coarse grid
        let inst = test_instance(3);
        let grid = grid_search(&inst, 17, 1e-9).unwrap();
        let alg = solve(&inst, 1e-9).unwrap();
        assert!(
            alg.efficiency >= grid.efficiency * (1.0 - 5e-3),
            "alg {} vs grid {}",
            alg.efficiency,
            grid.efficiency
        );
    }

    #[test]
    fn grid_feasible() {
        let inst = test_instance(3);
        let g = grid_search(&inst, 9, 1e-9).unwrap();
        assert!(g.solution.tau_ul.iter().sum::<f64>() <= inst.frame_ul * (1.0 + 1e-6));
        assert!(g.evals == 9usize.pow(3));
    }

    #[test]
    fn grid_on_gpu_instance() {
        let mut inst = test_instance(3);
        for d in &mut inst.devices {
            d.offset = 0.05;
            d.b_min = 16.0;
            d.speed = 300.0;
        }
        let grid = grid_search(&inst, 17, 1e-9).unwrap();
        let alg = solve(&inst, 1e-9).unwrap();
        assert!(
            alg.efficiency >= grid.efficiency * (1.0 - 5e-3),
            "alg {} vs grid {}",
            alg.efficiency,
            grid.efficiency
        );
    }
}
