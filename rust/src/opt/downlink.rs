//! Subproblem P3 (paper §IV-C, Theorem 2): downlink slot allocation.
//!
//! Time domain: find the minimal subperiod-2 makespan `T` with
//!   tau_k(T) = s T_f^D / (R_k^D (T - u_k))   (u_k = update latency)
//! packing the frame: `sum tau_k = T_f^D`. The paper's E^D* = T / dL.
//! `sum tau(T)` is strictly decreasing in T on (max u_k, inf), so a single
//! bisection suffices (Theorem 2's one-dimensional condition).

use anyhow::{bail, Result};

use super::types::Instance;

/// Downlink solution: slot allocation + subperiod-2 makespan.
#[derive(Clone, Debug)]
pub struct DownlinkSol {
    pub tau: Vec<f64>,
    pub t_down: f64,
}

/// Theorem 2 slot policy at makespan T; None if T <= some u_k.
pub fn tau_policy_dl(inst: &Instance, t: f64) -> Option<Vec<f64>> {
    let mut tau = Vec::with_capacity(inst.k());
    for d in &inst.devices {
        let headroom = t - d.update_lat;
        if headroom <= 0.0 {
            return None;
        }
        tau.push(inst.s_bits * inst.frame_dl / (d.rate_dl * headroom));
    }
    Some(tau)
}

/// Solve P3: minimal t_down with the Theorem-2 structure.
pub fn solve_downlink(inst: &Instance, eps: f64) -> Result<DownlinkSol> {
    let u_max = inst
        .devices
        .iter()
        .map(|d| d.update_lat)
        .fold(0.0f64, f64::max);
    let mut t_lo = u_max;
    let mut t_hi = u_max + 1.0;
    for _ in 0..200 {
        match tau_policy_dl(inst, t_hi) {
            Some(tau) if tau.iter().sum::<f64>() <= inst.frame_dl => break,
            _ => t_hi *= 2.0,
        }
        if t_hi > 1e12 {
            bail!("downlink infeasible");
        }
    }
    for _ in 0..300 {
        let mid = 0.5 * (t_lo + t_hi);
        match tau_policy_dl(inst, mid) {
            Some(tau) if tau.iter().sum::<f64>() <= inst.frame_dl => t_hi = mid,
            _ => t_lo = mid,
        }
        if (t_hi - t_lo) < eps * t_hi.max(1e-12) {
            break;
        }
    }
    let tau = tau_policy_dl(inst, t_hi)
        .ok_or_else(|| anyhow::anyhow!("downlink bisection failed"))?;
    Ok(DownlinkSol { tau, t_down: t_hi })
}

/// Makespan under *fixed* downlink slots: max_k (t^D_k + u_k).
pub fn makespan_fixed_slots_dl(inst: &Instance, tau: &[f64]) -> f64 {
    inst.devices
        .iter()
        .zip(tau)
        .map(|(d, &tk)| {
            let t_comm = if tk > 0.0 {
                inst.s_bits * inst.frame_dl / (tk * d.rate_dl)
            } else {
                f64::INFINITY
            };
            t_comm + d.update_lat
        })
        .fold(0.0f64, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::types::test_instance;

    #[test]
    fn packs_frame_exactly() {
        let inst = test_instance(6);
        let sol = solve_downlink(&inst, 1e-10).unwrap();
        let total: f64 = sol.tau.iter().sum();
        assert!((total - inst.frame_dl).abs() < 1e-6 * inst.frame_dl, "{total}");
    }

    #[test]
    fn synchronous_completion() {
        // Remark 5: every device finishes subperiod 2 at the same time.
        let inst = test_instance(6);
        let sol = solve_downlink(&inst, 1e-10).unwrap();
        for (d, &tk) in inst.devices.iter().zip(&sol.tau) {
            let t = inst.s_bits * inst.frame_dl / (tk * d.rate_dl) + d.update_lat;
            assert!((t - sol.t_down).abs() < 1e-6 * sol.t_down);
        }
    }

    #[test]
    fn better_rate_less_slot() {
        // Remark 5: slot decreases with the downlink rate (equal u_k).
        let mut inst = test_instance(6);
        for d in &mut inst.devices {
            d.update_lat = 0.02;
        }
        let sol = solve_downlink(&inst, 1e-10).unwrap();
        for i in 0..inst.k() {
            for j in 0..inst.k() {
                if inst.devices[i].rate_dl > inst.devices[j].rate_dl {
                    assert!(sol.tau[i] < sol.tau[j]);
                }
            }
        }
    }

    #[test]
    fn beats_equal_slots() {
        let inst = test_instance(6);
        let sol = solve_downlink(&inst, 1e-10).unwrap();
        let equal = vec![inst.frame_dl / 6.0; 6];
        let t_eq = makespan_fixed_slots_dl(&inst, &equal);
        assert!(sol.t_down <= t_eq * (1.0 + 1e-9), "{} vs {t_eq}", sol.t_down);
    }

    #[test]
    fn makespan_exceeds_slowest_update() {
        let mut inst = test_instance(4);
        inst.devices[2].update_lat = 0.5;
        let sol = solve_downlink(&inst, 1e-10).unwrap();
        assert!(sol.t_down > 0.5);
    }
}
