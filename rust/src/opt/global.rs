//! The outer problem P1: choose the global batch B maximizing learning
//! efficiency `E(B) = xi*sqrt(B) / (t_up(B) + t_down)` (paper §IV-C: after
//! substituting the subproblem solutions, P1 degrades to a univariate
//! problem in B).
//!
//! `t_down` is independent of B; `t_up(B)` comes from Algorithm 1. The
//! paper suggests Newton's method; we use golden-section search (derivative
//! free, robust to the kinks the box constraints introduce), plus an
//! optional verification scan used by the ablation bench.

use anyhow::Result;

use super::downlink::solve_downlink;
use super::types::{Instance, Solution};
use super::uplink::{assemble, solve_uplink};

/// Full period solution with the optimized global batch.
#[derive(Clone, Debug)]
pub struct GlobalSol {
    pub solution: Solution,
    /// the achieved learning efficiency E = dL/T
    pub efficiency: f64,
    /// number of uplink solves performed (complexity telemetry)
    pub evals: usize,
}

/// Learning efficiency at a given global batch (negative if infeasible).
fn efficiency_at(inst: &Instance, b: f64, t_down: f64, eps: f64) -> Option<(f64, Solution)> {
    let ul = solve_uplink(inst, b, eps).ok()?;
    let t_total = ul.t_up + t_down;
    let eff = inst.loss_decay(b) / t_total;
    let sol = assemble(ul, Vec::new(), t_down);
    Some((eff, sol))
}

/// Solve P1 end to end: downlink once, golden-section over B, reattach the
/// downlink slots.
pub fn solve(inst: &Instance, eps: f64) -> Result<GlobalSol> {
    let dl = solve_downlink(inst, eps)?;
    let (b_lo, b_hi) = inst.batch_range();
    let mut evals = 0usize;
    let mut eval = |b: f64| -> Option<(f64, Solution)> {
        evals += 1;
        efficiency_at(inst, b, dl.t_down, eps)
    };

    // golden-section maximize over [b_lo, b_hi]
    const PHI: f64 = 0.618_033_988_749_894_8;
    let mut a = b_lo;
    let mut b = b_hi;
    let mut x1 = b - PHI * (b - a);
    let mut x2 = a + PHI * (b - a);
    let mut f1 = eval(x1).map(|(e, _)| e).unwrap_or(f64::NEG_INFINITY);
    let mut f2 = eval(x2).map(|(e, _)| e).unwrap_or(f64::NEG_INFINITY);
    for _ in 0..200 {
        if (b - a) < 0.5 {
            break; // half-sample resolution is below batch quantization
        }
        if f1 < f2 {
            a = x1;
            x1 = x2;
            f1 = f2;
            x2 = a + PHI * (b - a);
            f2 = eval(x2).map(|(e, _)| e).unwrap_or(f64::NEG_INFINITY);
        } else {
            b = x2;
            x2 = x1;
            f2 = f1;
            x1 = b - PHI * (b - a);
            f1 = eval(x1).map(|(e, _)| e).unwrap_or(f64::NEG_INFINITY);
        }
    }
    let b_star = 0.5 * (a + b);
    let (eff, mut sol) =
        eval(b_star).ok_or_else(|| anyhow::anyhow!("global solve infeasible at B={b_star}"))?;
    sol.tau_dl = dl.tau;
    Ok(GlobalSol { solution: sol, efficiency: eff, evals })
}

/// Solve the allocation for a *fixed* global batch (used by schemes that
/// pin B, and by Fig. 3's per-period driver once B* is known).
pub fn solve_fixed_batch(inst: &Instance, b: f64, eps: f64) -> Result<GlobalSol> {
    let dl = solve_downlink(inst, eps)?;
    let ul = solve_uplink(inst, b, eps)?;
    let t_total = ul.t_up + dl.t_down;
    let eff = inst.loss_decay(b) / t_total;
    let mut sol = assemble(ul, Vec::new(), dl.t_down);
    sol.tau_dl = dl.tau;
    Ok(GlobalSol { solution: sol, efficiency: eff, evals: 1 })
}

/// Dense scan of E(B) (ablation/verification; `n` samples).
pub fn efficiency_scan(inst: &Instance, n: usize, eps: f64) -> Result<Vec<(f64, f64)>> {
    let dl = solve_downlink(inst, eps)?;
    let (b_lo, b_hi) = inst.batch_range();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let b = b_lo + (b_hi - b_lo) * i as f64 / (n - 1) as f64;
        if let Some((e, _)) = efficiency_at(inst, b, dl.t_down, eps) {
            out.push((b, e));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::types::test_instance;

    const EPS: f64 = 1e-9;

    #[test]
    fn global_beats_endpoints() {
        let inst = test_instance(6);
        let g = solve(&inst, EPS).unwrap();
        let (b_lo, b_hi) = inst.batch_range();
        let e_lo = solve_fixed_batch(&inst, b_lo, EPS).unwrap().efficiency;
        let e_hi = solve_fixed_batch(&inst, b_hi, EPS).unwrap().efficiency;
        assert!(g.efficiency >= e_lo - 1e-9, "{} vs lo {e_lo}", g.efficiency);
        assert!(g.efficiency >= e_hi - 1e-9, "{} vs hi {e_hi}", g.efficiency);
    }

    #[test]
    fn global_matches_dense_scan() {
        let inst = test_instance(6);
        let g = solve(&inst, EPS).unwrap();
        let scan = efficiency_scan(&inst, 200, EPS).unwrap();
        let best_scan = scan.iter().map(|&(_, e)| e).fold(f64::NEG_INFINITY, f64::max);
        assert!(
            g.efficiency >= best_scan * (1.0 - 1e-3),
            "golden {} vs scan {best_scan}",
            g.efficiency
        );
    }

    #[test]
    fn solution_fully_feasible() {
        let inst = test_instance(8);
        let g = solve(&inst, EPS).unwrap();
        let s = &g.solution;
        assert!(s.tau_ul.iter().sum::<f64>() <= inst.frame_ul * (1.0 + 1e-6));
        assert!(s.tau_dl.iter().sum::<f64>() <= inst.frame_dl * (1.0 + 1e-6));
        for (b, d) in s.batches.iter().zip(&inst.devices) {
            assert!(*b >= d.b_min - 1e-9 && *b <= d.b_max + 1e-9);
        }
        assert!(s.t_up > 0.0 && s.t_down > 0.0);
        assert!((s.efficiency(inst.xi) - g.efficiency).abs() < 1e-9);
    }

    #[test]
    fn efficiency_positive_and_finite() {
        for k in [2, 6, 12, 24] {
            let inst = test_instance(k);
            let g = solve(&inst, EPS).unwrap();
            assert!(g.efficiency.is_finite() && g.efficiency > 0.0, "k={k}");
        }
    }

    #[test]
    fn better_channel_higher_efficiency() {
        let inst = test_instance(6);
        let mut better = inst.clone();
        for d in &mut better.devices {
            d.rate_ul *= 4.0;
            d.rate_dl *= 4.0;
        }
        let e1 = solve(&inst, EPS).unwrap().efficiency;
        let e2 = solve(&better, EPS).unwrap().efficiency;
        assert!(e2 > e1);
    }

    #[test]
    fn faster_compute_higher_efficiency() {
        let inst = test_instance(6);
        let mut faster = inst.clone();
        for d in &mut faster.devices {
            d.speed *= 3.0;
        }
        let e1 = solve(&inst, EPS).unwrap().efficiency;
        let e2 = solve(&faster, EPS).unwrap().efficiency;
        assert!(e2 > e1);
    }
}
