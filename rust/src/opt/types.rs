//! Problem instance types for the paper's training-acceleration problem P1.
//!
//! The CPU and GPU scenarios share one structure (the paper's §V reduction,
//! Lemma 2): gradient-calculation latency is affine on the feasible batch
//! region, `t^L_k(B) = B / speed_k + offset_k` with `B in [b_min_k, b_max]`
//! — CPU: speed = f/C^L, offset = 0, b_min = 1; GPU: speed = 1/c,
//! offset = t_l - c*B_th, b_min = B_th.

use anyhow::{bail, Result};

use crate::device::Device;
use crate::wireless::PeriodRates;

/// Per-device optimizer view for one training period.
#[derive(Clone, Copy, Debug)]
pub struct DeviceInst {
    /// affine training speed V_k (samples/s)
    pub speed: f64,
    /// affine latency offset (s); 0 for CPU
    pub offset: f64,
    /// feasible batch floor (1 for CPU, B_th for GPU per Lemma 2)
    pub b_min: f64,
    /// batch ceiling B^max
    pub b_max: f64,
    /// average uplink rate R^U_k (bit/s)
    pub rate_ul: f64,
    /// average downlink rate R^D_k (bit/s)
    pub rate_dl: f64,
    /// local model update latency t^M_k (s)
    pub update_lat: f64,
}

/// One period's full problem instance.
#[derive(Clone, Debug)]
pub struct Instance {
    pub devices: Vec<DeviceInst>,
    /// compressed gradient size s = r*d*p (bits)
    pub s_bits: f64,
    /// uplink frame length T_f^U (s)
    pub frame_ul: f64,
    /// downlink frame length T_f^D (s)
    pub frame_dl: f64,
    /// loss-decay coefficient xi in dL = xi*sqrt(B)
    pub xi: f64,
}

impl Instance {
    /// Build from a device fleet and this period's rates.
    pub fn from_fleet(
        fleet: &[Device],
        rates: &[PeriodRates],
        b_max: f64,
        s_bits: f64,
        frame_ul: f64,
        frame_dl: f64,
        xi: f64,
    ) -> Result<Instance> {
        if fleet.is_empty() || fleet.len() != rates.len() {
            bail!("fleet/rates mismatch: {} vs {}", fleet.len(), rates.len());
        }
        let devices = fleet
            .iter()
            .zip(rates)
            .map(|(d, r)| {
                let (speed, offset) = d.compute.affine();
                DeviceInst {
                    speed,
                    offset,
                    b_min: d.compute.batch_floor(),
                    b_max,
                    rate_ul: r.ul_bps,
                    rate_dl: r.dl_bps,
                    update_lat: d.compute.update_latency(),
                }
            })
            .collect();
        let inst = Instance { devices, s_bits, frame_ul, frame_dl, xi };
        inst.validate()?;
        Ok(inst)
    }

    /// Build from a *sampled subset* of a fleet: `ids[i]` is the global
    /// device index the instance's device `i` describes, `rates[i]` its
    /// rates. The optimizer then allocates batches and TDMA band over the
    /// participants only — absent devices consume neither compute nor
    /// slots. Identity mapping over the whole fleet reproduces
    /// [`Instance::from_fleet`] bitwise (same per-device arithmetic, same
    /// order).
    pub fn from_fleet_ids(
        fleet: &[Device],
        ids: &[usize],
        rates: &[PeriodRates],
        b_max: f64,
        s_bits: f64,
        frame_ul: f64,
        frame_dl: f64,
        xi: f64,
    ) -> Result<Instance> {
        if ids.is_empty() || ids.len() != rates.len() {
            bail!("sampled ids/rates mismatch: {} vs {}", ids.len(), rates.len());
        }
        let devices = ids
            .iter()
            .zip(rates)
            .map(|(&g, r)| {
                let d = fleet
                    .get(g)
                    .ok_or_else(|| anyhow::anyhow!("sampled id {g} outside fleet"))?;
                let (speed, offset) = d.compute.affine();
                Ok(DeviceInst {
                    speed,
                    offset,
                    b_min: d.compute.batch_floor(),
                    b_max,
                    rate_ul: r.ul_bps,
                    rate_dl: r.dl_bps,
                    update_lat: d.compute.update_latency(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let inst = Instance { devices, s_bits, frame_ul, frame_dl, xi };
        inst.validate()?;
        Ok(inst)
    }

    pub fn validate(&self) -> Result<()> {
        if self.devices.is_empty() {
            bail!("no devices");
        }
        if !(self.s_bits > 0.0 && self.frame_ul > 0.0 && self.frame_dl > 0.0 && self.xi > 0.0) {
            bail!("non-positive instance globals");
        }
        for (k, d) in self.devices.iter().enumerate() {
            if !(d.speed > 0.0 && d.rate_ul > 0.0 && d.rate_dl > 0.0) {
                bail!("device {k}: non-positive speed/rate");
            }
            if !(d.b_min >= 1.0 && d.b_max >= d.b_min) {
                bail!("device {k}: bad batch bounds [{}, {}]", d.b_min, d.b_max);
            }
            if d.offset < 0.0 || d.update_lat < 0.0 {
                bail!("device {k}: negative latency term");
            }
        }
        Ok(())
    }

    pub fn k(&self) -> usize {
        self.devices.len()
    }

    /// Global-batch feasible interval [sum b_min, sum b_max].
    pub fn batch_range(&self) -> (f64, f64) {
        (
            self.devices.iter().map(|d| d.b_min).sum(),
            self.devices.iter().map(|d| d.b_max).sum(),
        )
    }

    /// Training-priority weights rho_k = V_k / sum V (paper's rho via
    /// f_k/C^L; identical when C^L is shared, generalized for GPU speeds).
    pub fn rho(&self) -> Vec<f64> {
        let total: f64 = self.devices.iter().map(|d| d.speed).sum();
        self.devices.iter().map(|d| d.speed / total).collect()
    }

    /// Loss decay dL = xi*sqrt(B) (eq. 8).
    pub fn loss_decay(&self, b: f64) -> f64 {
        self.xi * b.sqrt()
    }

    /// Gradient-calculation latency of device k at batch b (affine view).
    pub fn grad_latency(&self, k: usize, b: f64) -> f64 {
        let d = &self.devices[k];
        b / d.speed + d.offset
    }
}

/// The optimizer's per-device timing prediction for one period: where the
/// plan expects each device's simulated seconds to go. Captured on the
/// `Plan` so the audit ledger can hold predicted values against the
/// scheduler's realized ones (`obs/audit.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PredictedTiming {
    /// local gradient-computation time `offset + B/V` (s)
    pub compute: f64,
    /// slotted upload time `bits * T_f / (tau * R)` (s); +inf when the
    /// device holds no slot (mirrors the finish-time convention), 0 for
    /// communication-free schemes
    pub comm: f64,
    /// TDMA slot share `tau / T_f` in [0, 1]; 0 when the device holds no
    /// slot
    pub slot_share: f64,
}

/// Predicted per-device timings under the slot vector `tau_ul` for an
/// upload of `bits` per device — the same affine-compute + slotted-upload
/// terms [`uplink_finish_times`](crate::coordinator::scheme) folds into
/// arrival times, kept separate here so the audit ledger can decompose a
/// period into compute vs communication.
pub fn predicted_timings(
    inst: &Instance,
    batches: &[f64],
    tau_ul: &[f64],
    bits: f64,
) -> Vec<PredictedTiming> {
    inst.devices
        .iter()
        .zip(batches)
        .zip(tau_ul)
        .map(|((d, &b), &tk)| PredictedTiming {
            compute: d.offset + b / d.speed,
            comm: if tk > 0.0 {
                bits * inst.frame_ul / (tk * d.rate_ul)
            } else {
                f64::INFINITY
            },
            slot_share: if tk > 0.0 { tk / inst.frame_ul } else { 0.0 },
        })
        .collect()
}

/// Joint solution of one period's allocation problem.
#[derive(Clone, Debug)]
pub struct Solution {
    /// per-device batchsizes (continuous; quantize for execution)
    pub batches: Vec<f64>,
    /// uplink slot durations (s), sum <= frame_ul
    pub tau_ul: Vec<f64>,
    /// downlink slot durations (s), sum <= frame_dl
    pub tau_dl: Vec<f64>,
    /// makespan of subperiod 1 (local grad + upload), seconds
    pub t_up: f64,
    /// makespan of subperiod 2 (download + update), seconds
    pub t_down: f64,
    /// global batch B = sum batches
    pub b_total: f64,
}

impl Solution {
    /// End-to-end period latency T (eq. 14).
    pub fn period_latency(&self) -> f64 {
        self.t_up + self.t_down
    }

    /// Learning efficiency E = dL / T (eq. 15) for coefficient `xi`.
    pub fn efficiency(&self, xi: f64) -> f64 {
        xi * self.b_total.sqrt() / self.period_latency()
    }

    /// Round continuous batches to integers preserving the total
    /// (largest-remainder method) and respecting per-device bounds.
    pub fn quantized_batches(&self, inst: &Instance) -> Vec<usize> {
        quantize(&self.batches, inst)
    }
}

/// Largest-remainder rounding of a batch vector under box constraints.
pub fn quantize(batches: &[f64], inst: &Instance) -> Vec<usize> {
    // integer box: [ceil(b_min), floor(b_max)] per device (GPU B_th can be
    // fractional; rounding down would leave the data-bound region)
    let mut out: Vec<usize> = batches
        .iter()
        .zip(&inst.devices)
        .map(|(&b, d)| (b.floor().max(d.b_min.ceil()) as usize).min(d.b_max.floor() as usize))
        .collect();
    let target: usize = batches.iter().sum::<f64>().round() as usize;
    let mut have: usize = out.iter().sum();
    // distribute the remainder to the largest fractional parts first
    let mut order: Vec<usize> = (0..batches.len()).collect();
    order.sort_by(|&a, &b| {
        let fa = batches[a] - batches[a].floor();
        let fb = batches[b] - batches[b].floor();
        // total order (no NaN panic); fractional parts are never -0.0,
        // so normal values order exactly as before
        fb.total_cmp(&fa)
    });
    let mut i = 0;
    while have < target && i < 10 * out.len() {
        let k = order[i % order.len()];
        if ((out[k] + 1) as f64) <= inst.devices[k].b_max {
            out[k] += 1;
            have += 1;
        }
        i += 1;
    }
    let mut i = 0;
    while have > target && i < 10 * out.len() {
        let k = order[order.len() - 1 - (i % order.len())];
        if ((out[k] - 1) as f64) >= inst.devices[k].b_min {
            out[k] -= 1;
            have -= 1;
        }
        i += 1;
    }
    out
}

/// A convenient homogeneous test instance.
#[cfg(test)]
pub fn test_instance(k: usize) -> Instance {
    let devices = (0..k)
        .map(|i| DeviceInst {
            speed: 20.0 * (1.0 + (i % 3) as f64), // 20/40/60 samples/s
            offset: 0.0,
            b_min: 1.0,
            b_max: 128.0,
            rate_ul: 5e6 * (1.0 + (i % 4) as f64 * 0.5),
            rate_dl: 8e6 * (1.0 + (i % 2) as f64),
            update_lat: 0.02,
        })
        .collect();
    Instance {
        devices,
        s_bits: 0.005 * 64.0 * 570_000.0, // r*d*p
        frame_ul: 0.01,
        frame_dl: 0.01,
        xi: 0.05,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_range_and_rho() {
        let inst = test_instance(6);
        let (lo, hi) = inst.batch_range();
        assert_eq!(lo, 6.0);
        assert_eq!(hi, 6.0 * 128.0);
        let rho = inst.rho();
        assert!((rho.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(rho.iter().all(|&r| r > 0.0));
    }

    #[test]
    fn subset_instance_matches_full_rows_and_guards_ids() {
        use crate::device::paper_cpu_fleet;
        use crate::util::rng::Pcg;
        use crate::wireless::CellConfig;
        let mut rng = Pcg::seeded(3);
        let mut fleet = paper_cpu_fleet(5, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
        let rates: Vec<_> = {
            let r = &mut rng;
            fleet.iter_mut().map(|d| d.link.step(r)).collect()
        };
        let full = Instance::from_fleet(&fleet, &rates, 128.0, 1e5, 0.01, 0.01, 0.05).unwrap();
        let subset = |ids: &[usize], rs: &[PeriodRates]| {
            Instance::from_fleet_ids(&fleet, ids, rs, 128.0, 1e5, 0.01, 0.01, 0.05)
        };
        // identity mapping: bitwise the full constructor
        let ids: Vec<usize> = (0..5).collect();
        let ident = subset(&ids, &rates).unwrap();
        for (a, b) in full.devices.iter().zip(&ident.devices) {
            assert_eq!(a.speed.to_bits(), b.speed.to_bits());
            assert_eq!(a.rate_ul.to_bits(), b.rate_ul.to_bits());
            assert_eq!(a.update_lat.to_bits(), b.update_lat.to_bits());
        }
        // a strict subset picks exactly the named devices' compute rows
        let sub_rates = [rates[1], rates[4]];
        let sub = subset(&[1, 4], &sub_rates).unwrap();
        assert_eq!(sub.k(), 2);
        assert_eq!(sub.devices[0].speed.to_bits(), full.devices[1].speed.to_bits());
        assert_eq!(sub.devices[1].speed.to_bits(), full.devices[4].speed.to_bits());
        // empty, mismatched, and out-of-range id sets are rejected
        assert!(subset(&[], &[]).is_err());
        assert!(subset(&[0, 1], &sub_rates[..1]).is_err());
        assert!(subset(&[9], &sub_rates[..1]).is_err());
    }

    #[test]
    fn validate_rejects_bad() {
        let mut inst = test_instance(3);
        inst.devices[1].speed = 0.0;
        assert!(inst.validate().is_err());
        let mut inst = test_instance(3);
        inst.devices[0].b_min = 0.5;
        assert!(inst.validate().is_err());
        let mut inst = test_instance(3);
        inst.xi = -1.0;
        assert!(inst.validate().is_err());
    }

    #[test]
    fn quantize_preserves_total() {
        let inst = test_instance(5);
        let batches = vec![10.3, 20.7, 5.5, 64.25, 27.25];
        let q = quantize(&batches, &inst);
        let total: usize = q.iter().sum();
        assert_eq!(total, 128);
        for (qi, d) in q.iter().zip(&inst.devices) {
            assert!(*qi as f64 >= d.b_min && *qi as f64 <= d.b_max);
        }
    }

    #[test]
    fn quantize_respects_bounds() {
        let inst = test_instance(3);
        let q = quantize(&[0.2, 0.9, 1.9], &inst);
        assert!(q.iter().all(|&b| b >= 1));
    }

    #[test]
    fn predicted_timings_decompose_compute_and_comm() {
        let inst = test_instance(3);
        let batches = vec![20.0, 40.0, 60.0];
        let tau = vec![0.004, 0.003, 0.0];
        let pts = predicted_timings(&inst, &batches, &tau, 1e5);
        assert_eq!(pts.len(), 3);
        // compute is the affine latency, bitwise
        for (k, pt) in pts.iter().enumerate() {
            assert_eq!(pt.compute.to_bits(), inst.grad_latency(k, batches[k]).to_bits());
        }
        // a positive slot prices the upload; slot share is tau / frame
        assert!((pts[0].comm - 1e5 * 0.01 / (0.004 * inst.devices[0].rate_ul)).abs() < 1e-12);
        assert!((pts[0].slot_share - 0.4).abs() < 1e-12);
        // a zero slot never uploads: +inf comm, zero share
        assert_eq!(pts[2].comm, f64::INFINITY);
        assert_eq!(pts[2].slot_share, 0.0);
        // the default is the all-zero row (scatter filler for unsampled
        // devices)
        assert_eq!(PredictedTiming::default(), PredictedTiming {
            compute: 0.0,
            comm: 0.0,
            slot_share: 0.0
        });
    }

    #[test]
    fn efficiency_formula() {
        let sol = Solution {
            batches: vec![50.0, 50.0],
            tau_ul: vec![0.005, 0.005],
            tau_dl: vec![0.005, 0.005],
            t_up: 2.0,
            t_down: 0.5,
            b_total: 100.0,
        };
        assert!((sol.period_latency() - 2.5).abs() < 1e-12);
        assert!((sol.efficiency(0.05) - 0.05 * 10.0 / 2.5).abs() < 1e-12);
    }
}
