//! Corollary 1 and 2 (paper Appendix B): search bounds for E^U* and mu*.
//!
//! Stated in the time domain (`T = dL * E^U`), zero-offset (CPU) form —
//! exactly the setting of the paper's corollaries. These initialize/verify
//! Algorithm 1's bisection brackets; the production solver additionally
//! copes with offsets by bracket doubling (opt::uplink).

use super::types::Instance;

/// Corollary 1 (time domain): bounds on the subperiod-1 makespan
/// `T* = dL*E^U*` for global batch `b`. Returns (lower, upper).
pub fn makespan_bounds(inst: &Instance, b: f64) -> (f64, f64) {
    let k = inst.k() as f64;
    let total_speed: f64 = inst.devices.iter().map(|d| d.speed).sum();
    let rho = inst.rho();
    // lower (infinite-memory relaxation): B/(sum V) + s (sum sqrt(rho/R))^2
    let comm: f64 = inst
        .devices
        .iter()
        .zip(&rho)
        .map(|(d, &r)| (r / (d.rate_ul * inst.frame_ul / inst.frame_ul)).sqrt())
        .sum();
    let lower = b / total_speed + inst.s_bits * comm * comm;
    // upper (equal split): max_k B/(K V_k) + K s / R_k
    let upper = inst
        .devices
        .iter()
        .map(|d| d.offset + b / (k * d.speed) + k * inst.s_bits / d.rate_ul)
        .fold(0.0f64, f64::max);
    (lower, upper)
}

/// Corollary 2 (time domain, mu rescaled by dL as in opt::uplink): given a
/// candidate makespan `t`, the inner multiplier bracket [mu_lo, mu_hi]
/// outside which every device clamps to b_max / b_min respectively.
pub fn mu_bounds(inst: &Instance, t: f64) -> (f64, f64) {
    let rho = inst.rho();
    let mut lo = f64::INFINITY;
    let mut hi: f64 = 0.0;
    for (d, &r) in inst.devices.iter().zip(&rho) {
        let c = r * d.rate_ul / (inst.s_bits * inst.frame_ul);
        // B_k = V (t - off - sqrt(mu / (c))) = b  =>  mu = c (t - off - b/V)^2
        let at = |bk: f64| {
            let x = t - d.offset - bk / d.speed;
            if x <= 0.0 {
                0.0
            } else {
                c * x * x
            }
        };
        lo = lo.min(at(d.b_max));
        hi = hi.max(at(d.b_min));
    }
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::types::test_instance;
    use crate::opt::uplink::{batch_policy, solve_uplink};

    #[test]
    fn corollary1_brackets_optimum() {
        let inst = test_instance(6); // CPU-form: offsets 0
        for b in [50.0, 200.0, 500.0] {
            let sol = solve_uplink(&inst, b, 1e-10).unwrap();
            let (lo, hi) = makespan_bounds(&inst, b);
            assert!(
                sol.t_up >= lo * (1.0 - 1e-6),
                "B={b}: t_up {} below lower bound {lo}",
                sol.t_up
            );
            assert!(
                sol.t_up <= hi * (1.0 + 1e-6),
                "B={b}: t_up {} above upper bound {hi}",
                sol.t_up
            );
        }
    }

    #[test]
    fn corollary2_brackets_mu() {
        let inst = test_instance(6);
        let b = 300.0;
        let sol = solve_uplink(&inst, b, 1e-10).unwrap();
        // interior case required by the corollary: at least one device
        // strictly inside (b_min, b_max)
        let interior = sol
            .batches
            .iter()
            .any(|&bk| bk > 1.0 + 1e-6 && bk < 128.0 - 1e-6);
        assert!(interior, "test setup: want an interior device");
        let (lo, hi) = mu_bounds(&inst, sol.t_up);
        assert!(sol.mu >= lo - 1e-12, "mu {} < lo {lo}", sol.mu);
        assert!(sol.mu <= hi + 1e-12, "mu {} > hi {hi}", sol.mu);
    }

    #[test]
    fn mu_bounds_select_clamping() {
        // at mu > hi all batches clamp to b_min; at mu < lo all clamp to b_max
        let inst = test_instance(5);
        let t = 8.0;
        let (lo, hi) = mu_bounds(&inst, t);
        let rho = inst.rho();
        let bs_hi = batch_policy(&inst, &rho, t, hi * (1.0 + 1e-9) + 1e-15);
        for (bk, d) in bs_hi.iter().zip(&inst.devices) {
            assert!((*bk - d.b_min).abs() < 1e-6, "{bk}");
        }
        if lo > 0.0 {
            let bs_lo = batch_policy(&inst, &rho, t, lo * (1.0 - 1e-9));
            assert!(bs_lo
                .iter()
                .zip(&inst.devices)
                .any(|(bk, d)| (*bk - d.b_max).abs() < 1e-6));
        }
    }
}
