//! Subproblem P2 (paper §IV-B): joint batchsize selection + uplink slot
//! allocation — Theorem 1's closed forms inside Algorithm 1's
//! two-dimensional bisection.
//!
//! We work in the *time domain*: let `T` be the makespan of subperiod 1
//! (local gradient calculation + upload). The paper's `E^U` is `T / dL`
//! with `dL = xi*sqrt(B)`; minimizing one minimizes the other, and the
//! time form keeps the downlink subproblem independent of `B`.
//!
//! Theorem 1 (generalized with affine offsets, DESIGN.md §5 / Lemma 2):
//!   B_k*(T, mu) = clamp( V_k * (T - off_k - sqrt(mu * s T_f / (rho_k R_k))),
//!                        b_min_k, b_max_k )
//!   tau_k*(T)   = s T_f / (R_k (T - off_k - B_k*/V_k))   (active constraint)
//!
//! Outer bisection over T: total slot demand `sum tau_k(T)` decreases in T;
//! converge to `sum tau = T_f`. Inner bisection over mu >= 0: `sum B_k`
//! decreases in mu; converge to `sum B_k = B`.

use anyhow::{bail, Result};

use super::types::{Instance, Solution};

/// Solution of the uplink subproblem for a fixed global batch B.
#[derive(Clone, Debug)]
pub struct UplinkSol {
    pub batches: Vec<f64>,
    pub tau: Vec<f64>,
    /// subperiod-1 makespan (s); the paper's E^U* = t_up / (xi sqrt B)
    pub t_up: f64,
    /// converged inner multiplier (paper's mu*, time-domain scaled)
    pub mu: f64,
}

/// Closed-form batch policy at (T, mu) — Theorem 1, eq. (21) top.
pub fn batch_policy(inst: &Instance, rho: &[f64], t: f64, mu: f64) -> Vec<f64> {
    inst.devices
        .iter()
        .zip(rho)
        .map(|(d, &rho_k)| {
            let comm = (mu * inst.s_bits * inst.frame_ul / (rho_k * d.rate_ul)).sqrt();
            let b = d.speed * (t - d.offset - comm);
            b.clamp(d.b_min, d.b_max)
        })
        .collect()
}

/// Active-constraint slot durations at makespan T — Theorem 1, eq. (21)
/// bottom. Returns None if some device cannot finish its batch within T.
pub fn tau_policy(inst: &Instance, batches: &[f64], t: f64) -> Option<Vec<f64>> {
    let mut tau = Vec::with_capacity(inst.k());
    for (d, &b) in inst.devices.iter().zip(batches) {
        let headroom = t - d.offset - b / d.speed;
        if headroom <= 0.0 {
            return None;
        }
        tau.push(inst.s_bits * inst.frame_ul / (d.rate_ul * headroom));
    }
    Some(tau)
}

/// Inner 1-D search (paper's mu*): find mu >= 0 with `sum B_k(T,mu) = B`.
/// Returns (mu, batches). `sum B_k` is continuous, non-increasing in mu.
fn solve_mu(inst: &Instance, rho: &[f64], t: f64, b: f64, eps: f64) -> Option<(f64, Vec<f64>)> {
    let at = |mu: f64| -> (Vec<f64>, f64) {
        let bs = batch_policy(inst, rho, t, mu);
        let sum = bs.iter().sum::<f64>();
        (bs, sum)
    };
    let (bs0, sum0) = at(0.0);
    if sum0 < b - eps {
        return None; // even unconstrained comm can't reach B at this T
    }
    if sum0 <= b + eps {
        return Some((0.0, bs0));
    }
    // bracket: grow mu until sum <= b
    let mut hi = 1e-12;
    let mut lo = 0.0;
    for _ in 0..200 {
        let (_, s) = at(hi);
        if s <= b {
            break;
        }
        lo = hi;
        hi *= 4.0;
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        let (_, s) = at(mid);
        if s > b {
            lo = mid;
        } else {
            hi = mid;
        }
        if (hi - lo) < 1e-12 * (1.0 + hi) {
            break;
        }
    }
    let (bs, _) = at(hi);
    Some((hi, bs))
}

/// Algorithm 1: solve the uplink subproblem for global batch `b`.
pub fn solve_uplink(inst: &Instance, b: f64, eps: f64) -> Result<UplinkSol> {
    let (b_lo, b_hi) = inst.batch_range();
    if !(b_lo - 1e-9..=b_hi + 1e-9).contains(&b) {
        bail!("global batch {b} outside feasible [{b_lo}, {b_hi}]");
    }
    let rho = inst.rho();

    // Slot demand at makespan T (None => T infeasible, demand = +inf).
    let demand = |t: f64| -> Option<(f64, f64, Vec<f64>, Vec<f64>)> {
        let (mu, batches) = solve_mu(inst, &rho, t, b, eps)?;
        let tau = tau_policy(inst, &batches, t)?;
        let total: f64 = tau.iter().sum();
        Some((total, mu, batches, tau))
    };

    // Bracket T. Lower: no device can even compute its floor batch faster.
    let t_floor = inst
        .devices
        .iter()
        .map(|d| d.offset + d.b_min / d.speed)
        .fold(0.0f64, f64::max);
    let mut t_lo = t_floor;
    // Upper: start from the equal-split bound (Corollary 1 upper, time
    // domain) and double until the frame fits.
    let k = inst.k() as f64;
    let mut t_hi = inst
        .devices
        .iter()
        .map(|d| d.offset + b / (k * d.speed) + k * inst.s_bits / d.rate_ul)
        .fold(0.0f64, f64::max)
        .max(t_floor * 2.0 + 1e-6);
    for _ in 0..200 {
        match demand(t_hi) {
            Some((total, ..)) if total <= inst.frame_ul => break,
            _ => t_hi *= 2.0,
        }
        if t_hi > 1e12 {
            bail!("uplink subproblem infeasible: slot demand never fits the frame");
        }
    }

    // Outer bisection: sum tau(T) = T_f.
    let mut best: Option<(f64, f64, Vec<f64>, Vec<f64>)> = None;
    for _ in 0..300 {
        let t_mid = 0.5 * (t_lo + t_hi);
        match demand(t_mid) {
            Some((total, mu, batches, tau)) if total <= inst.frame_ul => {
                best = Some((t_mid, mu, batches, tau));
                t_hi = t_mid;
            }
            _ => t_lo = t_mid,
        }
        if (t_hi - t_lo) < eps * t_hi.max(1e-12) {
            break;
        }
    }
    let (t_up, mu, batches, tau) = match best {
        Some(x) => x,
        None => {
            let (total, mu, batches, tau) =
                demand(t_hi).ok_or_else(|| anyhow::anyhow!("uplink infeasible at t_hi"))?;
            if total > inst.frame_ul * (1.0 + 1e-6) {
                bail!("uplink bisection failed to find a feasible makespan");
            }
            (t_hi, mu, batches, tau)
        }
    };
    Ok(UplinkSol { batches, tau, t_up, mu })
}

/// Minimal subperiod-1 makespan for a *fixed* batch vector (used by the
/// grid-search reference and by fixed-batch baseline schemes): bisect T so
/// the active-constraint slot demand exactly fills the frame.
pub fn makespan_for_batches(inst: &Instance, batches: &[f64]) -> Result<(f64, Vec<f64>)> {
    if batches.len() != inst.k() {
        bail!("batch vector length mismatch");
    }
    let t_floor = inst
        .devices
        .iter()
        .zip(batches)
        .map(|(d, &b)| d.offset + b / d.speed)
        .fold(0.0f64, f64::max);
    let mut t_lo = t_floor;
    let mut t_hi = t_floor * 2.0 + 1.0;
    for _ in 0..200 {
        match tau_policy(inst, batches, t_hi) {
            Some(tau) if tau.iter().sum::<f64>() <= inst.frame_ul => break,
            _ => t_hi *= 2.0,
        }
        if t_hi > 1e12 {
            bail!("makespan_for_batches: infeasible");
        }
    }
    for _ in 0..200 {
        let mid = 0.5 * (t_lo + t_hi);
        match tau_policy(inst, batches, mid) {
            Some(tau) if tau.iter().sum::<f64>() <= inst.frame_ul => t_hi = mid,
            _ => t_lo = mid,
        }
        if (t_hi - t_lo) < 1e-12 * t_hi.max(1e-9) {
            break;
        }
    }
    let tau = tau_policy(inst, batches, t_hi)
        .ok_or_else(|| anyhow::anyhow!("makespan bisection failed"))?;
    Ok((t_hi, tau))
}

/// Makespan when slots are fixed (e.g. equal split): T = max_k t_L + t_U.
pub fn makespan_fixed_slots(inst: &Instance, batches: &[f64], tau: &[f64]) -> f64 {
    inst.devices
        .iter()
        .zip(batches)
        .zip(tau)
        .map(|((d, &b), &tk)| {
            let t_comm = if tk > 0.0 {
                inst.s_bits * inst.frame_ul / (tk * d.rate_ul)
            } else {
                f64::INFINITY
            };
            d.offset + b / d.speed + t_comm
        })
        .fold(0.0f64, f64::max)
}

/// Assemble a full `Solution` given uplink + downlink results.
pub fn assemble(ul: UplinkSol, tau_dl: Vec<f64>, t_down: f64) -> Solution {
    let b_total = ul.batches.iter().sum();
    Solution {
        batches: ul.batches,
        tau_ul: ul.tau,
        tau_dl,
        t_up: ul.t_up,
        t_down,
        b_total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::types::test_instance;

    const EPS: f64 = 1e-9;

    #[test]
    fn solution_feasible() {
        let inst = test_instance(6);
        let sol = solve_uplink(&inst, 300.0, EPS).unwrap();
        let total_b: f64 = sol.batches.iter().sum();
        assert!((total_b - 300.0).abs() < 1e-3, "sum B = {total_b}");
        let total_tau: f64 = sol.tau.iter().sum();
        assert!(total_tau <= inst.frame_ul * (1.0 + 1e-6), "tau sum {total_tau}");
        // every device must finish by t_up
        for (k, (d, &b)) in inst.devices.iter().zip(&sol.batches).enumerate() {
            let t = d.offset + b / d.speed + inst.s_bits * inst.frame_ul / (sol.tau[k] * d.rate_ul);
            assert!(t <= sol.t_up * (1.0 + 1e-6), "device {k}: {t} > {}", sol.t_up);
        }
    }

    #[test]
    fn makespan_synchronous() {
        // Theorem 1/Remark 3: the optimum equalizes completion times.
        let inst = test_instance(6);
        let sol = solve_uplink(&inst, 300.0, EPS).unwrap();
        for (k, (d, &b)) in inst.devices.iter().zip(&sol.batches).enumerate() {
            let t = d.offset + b / d.speed + inst.s_bits * inst.frame_ul / (sol.tau[k] * d.rate_ul);
            assert!(
                (t - sol.t_up).abs() < 1e-4 * sol.t_up,
                "device {k}: finishes at {t} vs makespan {}",
                sol.t_up
            );
        }
    }

    #[test]
    fn faster_device_larger_batch() {
        // Remark 2: batch scales with local training speed.
        let inst = test_instance(6);
        let sol = solve_uplink(&inst, 200.0, EPS).unwrap();
        // devices 0 and 3 share rate tiers? construct direct comparison:
        // device 2 (speed 60) vs device 0 (speed 20), same rate tier (i%4: 2 vs 0 differ)
        // use devices 0 (speed 20, rate 5e6) and 3 (speed 20*(1+0)=20? i%3 of 3 = 0 -> speed 20, rate 5e6*2.5)
        // instead check global correlation:
        let mut speed_order: Vec<usize> = (0..6).collect();
        speed_order.sort_by(|&a, &b| {
            inst.devices[a].speed.total_cmp(&inst.devices[b].speed)
        });
        let slowest = &sol.batches[speed_order[0]];
        let fastest = &sol.batches[*speed_order.last().unwrap()];
        assert!(fastest > slowest, "fastest {fastest} vs slowest {slowest}");
    }

    #[test]
    fn makespan_monotone_in_batch() {
        let inst = test_instance(6);
        let t1 = solve_uplink(&inst, 100.0, EPS).unwrap().t_up;
        let t2 = solve_uplink(&inst, 400.0, EPS).unwrap().t_up;
        assert!(t2 > t1);
    }

    #[test]
    fn extreme_batches_clamp() {
        let inst = test_instance(4);
        // B = K -> all floors
        let sol = solve_uplink(&inst, 4.0, EPS).unwrap();
        for &b in &sol.batches {
            assert!((b - 1.0).abs() < 1e-6);
        }
        // B = K * 128 -> all ceilings
        let sol = solve_uplink(&inst, 4.0 * 128.0, EPS).unwrap();
        for &b in &sol.batches {
            assert!((b - 128.0).abs() < 1e-6);
        }
    }

    #[test]
    fn out_of_range_batch_rejected() {
        let inst = test_instance(4);
        assert!(solve_uplink(&inst, 3.0, EPS).is_err());
        assert!(solve_uplink(&inst, 4.0 * 128.0 + 1.0, EPS).is_err());
    }

    #[test]
    fn fixed_batch_makespan_not_better_than_optimal_policy() {
        // the joint optimum at its own total B beats equal batches with
        // optimal slots at the same total B
        let inst = test_instance(6);
        let b = 300.0;
        let opt = solve_uplink(&inst, b, EPS).unwrap();
        let equal = vec![b / 6.0; 6];
        let (t_equal, _) = makespan_for_batches(&inst, &equal).unwrap();
        assert!(opt.t_up <= t_equal * (1.0 + 1e-6), "{} vs {t_equal}", opt.t_up);
    }

    #[test]
    fn fixed_slots_worse_than_optimal_slots() {
        let inst = test_instance(6);
        let b = 300.0;
        let opt = solve_uplink(&inst, b, EPS).unwrap();
        let equal_tau = vec![inst.frame_ul / 6.0; 6];
        let t_fixed = makespan_fixed_slots(&inst, &opt.batches, &equal_tau);
        assert!(opt.t_up <= t_fixed * (1.0 + 1e-6));
    }

    #[test]
    fn gpu_offsets_respected() {
        // GPU-style instance: offsets and batch floors (Lemma 2 region)
        let mut inst = test_instance(4);
        for d in &mut inst.devices {
            d.offset = 0.05;
            d.b_min = 16.0;
            d.speed = 400.0;
        }
        let sol = solve_uplink(&inst, 200.0, EPS).unwrap();
        for &b in &sol.batches {
            assert!(b >= 16.0 - 1e-9 && b <= 128.0 + 1e-9);
        }
        assert!(sol.t_up > 0.05);
        let total: f64 = sol.batches.iter().sum();
        assert!((total - 200.0).abs() < 1e-3);
    }
}
