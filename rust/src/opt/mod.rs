//! The paper's optimization contribution (DESIGN.md S6): learning-efficiency
//! maximization P1 via problem decomposition —
//!
//! * `uplink` — subproblem P2: Theorem 1 closed forms + Algorithm 1's
//!   two-dimensional bisection (joint batchsize + uplink slots);
//! * `downlink` — subproblem P3: Theorem 2 (downlink slots);
//! * `global` — the outer univariate optimization of the global batch B;
//! * `bounds` — Corollary 1/2 search brackets;
//! * `grid` — brute-force reference optimizer (tests/ablation);
//! * `baselines` — online/full/random/equal policies (Table II, Fig. 4-5);
//! * `types` — shared problem-instance plumbing (CPU/GPU unified per
//!   Lemma 2's affine reduction).

pub mod baselines;
pub mod bounds;
pub mod downlink;
pub mod global;
pub mod grid;
pub mod types;
pub mod uplink;

pub use baselines::BatchPolicy;
pub use downlink::{solve_downlink, DownlinkSol};
pub use global::{solve, solve_fixed_batch, GlobalSol};
pub use types::{predicted_timings, DeviceInst, Instance, PredictedTiming, Solution};
pub use uplink::{solve_uplink, UplinkSol};
