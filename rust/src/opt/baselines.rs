//! Baseline allocation policies the paper compares against (§VI-C/D):
//! online learning (B_k = 1), full batch (B_k = B_max), random batch, and
//! the decoupled ablations (equal slots and/or equal batches).
//!
//! All baselines receive *optimal slots for their fixed batches* by default
//! (fair comparison: the paper's gain is attributed to joint selection, not
//! to starving the baselines of scheduling); the `equal_slots` variants
//! quantify the slot-allocation half of the win for the ablation bench.

use anyhow::Result;

use super::downlink::{makespan_fixed_slots_dl, solve_downlink};
use super::types::{Instance, Solution};
use super::uplink::{makespan_fixed_slots, makespan_for_batches};
use crate::util::rng::Pcg;

/// Batch policies for the GPU-scenario comparison (Fig. 4/5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchPolicy {
    /// B_k = b_min (paper: 1 in the CPU scenario)
    Online,
    /// B_k = B^max = 128
    Full,
    /// B_k ~ U[b_min, b_max] each period
    Random,
    /// equal share of a given global batch
    Equal(usize),
}

/// Produce the baseline batch vector for this period.
pub fn batches_for(policy: BatchPolicy, inst: &Instance, rng: &mut Pcg) -> Vec<f64> {
    match policy {
        BatchPolicy::Online => inst.devices.iter().map(|d| d.b_min).collect(),
        BatchPolicy::Full => inst.devices.iter().map(|d| d.b_max).collect(),
        BatchPolicy::Random => inst
            .devices
            .iter()
            .map(|d| rng.range_f64(d.b_min, d.b_max + 1.0).floor().min(d.b_max))
            .collect(),
        BatchPolicy::Equal(b) => {
            let share = b as f64 / inst.k() as f64;
            inst.devices
                .iter()
                .map(|d| share.clamp(d.b_min, d.b_max))
                .collect()
        }
    }
}

/// Evaluate fixed batches with optimal slot allocation on both links.
pub fn solve_fixed_batches(inst: &Instance, batches: &[f64], eps: f64) -> Result<Solution> {
    let (t_up, tau_ul) = makespan_for_batches(inst, batches)?;
    let dl = solve_downlink(inst, eps)?;
    Ok(Solution {
        batches: batches.to_vec(),
        tau_ul,
        tau_dl: dl.tau,
        t_up,
        t_down: dl.t_down,
        b_total: batches.iter().sum(),
    })
}

/// Evaluate fixed batches with EQUAL slots on both links (ablation).
pub fn solve_equal_slots(inst: &Instance, batches: &[f64]) -> Solution {
    let k = inst.k();
    let tau_ul = vec![inst.frame_ul / k as f64; k];
    let tau_dl = vec![inst.frame_dl / k as f64; k];
    let t_up = makespan_fixed_slots(inst, batches, &tau_ul);
    let t_down = makespan_fixed_slots_dl(inst, &tau_dl);
    Solution {
        batches: batches.to_vec(),
        tau_ul,
        tau_dl,
        t_up,
        t_down,
        b_total: batches.iter().sum(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::global::solve;
    use crate::opt::types::test_instance;

    const EPS: f64 = 1e-9;

    #[test]
    fn proposed_dominates_all_baselines() {
        // The headline property behind Table II / Fig. 4-5.
        let inst = test_instance(6);
        let opt = solve(&inst, EPS).unwrap();
        let mut rng = Pcg::seeded(10);
        for policy in [
            BatchPolicy::Online,
            BatchPolicy::Full,
            BatchPolicy::Random,
            BatchPolicy::Equal(300),
        ] {
            let batches = batches_for(policy, &inst, &mut rng);
            let sol = solve_fixed_batches(&inst, &batches, EPS).unwrap();
            let eff = sol.efficiency(inst.xi);
            assert!(
                opt.efficiency >= eff * (1.0 - 1e-6),
                "{policy:?}: baseline {eff} beats proposed {}",
                opt.efficiency
            );
        }
    }

    #[test]
    fn equal_slots_never_better() {
        let inst = test_instance(6);
        let mut rng = Pcg::seeded(11);
        for policy in [BatchPolicy::Online, BatchPolicy::Full, BatchPolicy::Random] {
            let batches = batches_for(policy, &inst, &mut rng);
            let opt_slots = solve_fixed_batches(&inst, &batches, EPS).unwrap();
            let eq_slots = solve_equal_slots(&inst, &batches);
            assert!(
                opt_slots.period_latency() <= eq_slots.period_latency() * (1.0 + 1e-9),
                "{policy:?}"
            );
        }
    }

    #[test]
    fn random_batches_within_bounds() {
        let inst = test_instance(8);
        let mut rng = Pcg::seeded(12);
        for _ in 0..100 {
            let bs = batches_for(BatchPolicy::Random, &inst, &mut rng);
            for (b, d) in bs.iter().zip(&inst.devices) {
                assert!(*b >= d.b_min && *b <= d.b_max);
            }
        }
    }

    #[test]
    fn online_and_full_are_extremes() {
        let inst = test_instance(4);
        let mut rng = Pcg::seeded(13);
        let online = batches_for(BatchPolicy::Online, &inst, &mut rng);
        let full = batches_for(BatchPolicy::Full, &inst, &mut rng);
        assert!(online.iter().all(|&b| b == 1.0));
        assert!(full.iter().all(|&b| b == 128.0));
    }
}
