//! PJRT runtime benches: the production L1/L2 execution path — train_step
//! per batch bucket, apply_update (the Pallas SGD kernel), evaluate, and
//! host-model equivalents for comparison. Skips (with a notice) when
//! artifacts are absent.

use std::path::PathBuf;

use feel::benchkit::Bench;
use feel::coordinator::backend::{Backend, HostBackend, PjrtBackend};
use feel::runtime::Runtime;
use feel::util::rng::Pcg;

fn batch(n: usize, d: usize, c: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut r = Pcg::seeded(seed);
    (
        (0..n * d).map(|_| r.normal() as f32).collect(),
        (0..n).map(|_| r.below(c as u64) as i32).collect(),
    )
}

fn main() {
    let mut b = Bench::new("runtime");
    b.header();

    let dir = PathBuf::from(
        std::env::var("FEEL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    if !dir.join("manifest.json").exists() {
        println!("no artifacts at {} — run `make artifacts`; skipping PJRT benches", dir.display());
    } else {
        let rt = Runtime::load(&dir).unwrap();
        let model = "mini_res".to_string();
        let d = rt.manifest.input_dim;
        let c = rt.manifest.classes;
        let be = PjrtBackend::new(rt, &model).unwrap();
        let params = be.init_params().unwrap();

        for n in [1usize, 16, 64, 128] {
            let (x, y) = batch(n, d, c, n as u64);
            // warm the executable cache outside the timed region
            be.train_step(&params, &x, &y).unwrap();
            b.bench(&format!("pjrt_train_step_b{n}"), || {
                std::hint::black_box(be.train_step(&params, &x, &y).unwrap());
            });
        }

        let grads: Vec<f32> = params.iter().map(|p| p * 0.01).collect();
        be.apply_update(&params, &grads, 0.01).unwrap();
        b.bench("pjrt_apply_update_570k", || {
            std::hint::black_box(be.apply_update(&params, &grads, 0.01).unwrap());
        });

        let (ex, ey) = batch(256, d, c, 9);
        be.evaluate(&params, &ex, &ey).unwrap();
        b.bench("pjrt_evaluate_256", || {
            std::hint::black_box(be.evaluate(&params, &ex, &ey).unwrap());
        });

        // host-model comparison at the same geometry
        let host = HostBackend::for_model(&model, d, c, 0).unwrap();
        let hp = host.init_params().unwrap();
        let (x, y) = batch(64, d, c, 64);
        b.bench("host_train_step_b64", || {
            std::hint::black_box(host.train_step(&hp, &x, &y).unwrap());
        });
    }
}
