//! Optimizer benches: Algorithm 1's cost vs K (the paper claims
//! O((K log 1/eps)^2)-ish practicality), closed form vs grid search, and
//! the downlink/global solvers.

use feel::benchkit::Bench;
use feel::opt::types::{DeviceInst, Instance};
use feel::opt::{grid, solve, solve_downlink, solve_uplink};
use feel::util::rng::Pcg;

fn instance(k: usize, seed: u64) -> Instance {
    let mut rng = Pcg::seeded(seed);
    let devices = (0..k)
        .map(|_| DeviceInst {
            speed: rng.range_f64(10.0, 80.0),
            offset: 0.0,
            b_min: 1.0,
            b_max: 128.0,
            rate_ul: rng.range_f64(2e6, 40e6),
            rate_dl: rng.range_f64(4e6, 80e6),
            update_lat: rng.range_f64(0.005, 0.05),
        })
        .collect();
    Instance { devices, s_bits: 182_400.0, frame_ul: 0.01, frame_dl: 0.01, xi: 0.05 }
}

fn main() {
    let mut b = Bench::new("optimizer");
    b.header();

    for k in [2usize, 6, 12, 24, 48, 96] {
        let inst = instance(k, k as u64);
        b.bench(&format!("algorithm1_full_solve_k{k}"), || {
            std::hint::black_box(solve(&inst, 1e-6).unwrap());
        });
    }

    let inst = instance(12, 1);
    b.bench("uplink_subproblem_k12", || {
        std::hint::black_box(solve_uplink(&inst, 400.0, 1e-6).unwrap());
    });
    b.bench("downlink_subproblem_k12", || {
        std::hint::black_box(solve_downlink(&inst, 1e-6).unwrap());
    });

    // ablation: closed-form vs brute force (paper's optimality claim)
    let small = instance(3, 2);
    b.bench("grid_search_k3_17pts", || {
        std::hint::black_box(grid::grid_search(&small, 17, 1e-6).unwrap());
    });
    b.bench("algorithm1_k3", || {
        std::hint::black_box(solve(&small, 1e-6).unwrap());
    });
    let g = grid::grid_search(&small, 17, 1e-6).unwrap();
    let a = solve(&small, 1e-6).unwrap();
    println!(
        "\n  optimality: algorithm1 E={:.6} vs grid(17^3) E={:.6} (gap {:+.3}%)",
        a.efficiency,
        g.efficiency,
        100.0 * (a.efficiency - g.efficiency) / g.efficiency
    );
}
