//! GEMM kernel bench: the packed-tile microkernel (serial and threaded)
//! vs `gemm_ref`, the frozen pre-packing kernel, across square sizes and
//! the host-model's actual layer shapes. The acceptance bar for the
//! packed kernel is ≥ 3× over `gemm_ref` at 256³ and above (serial vs
//! serial, so the comparison isolates the kernel, not the fan-out).
//!
//! Emits a `BENCH_gemm.json` baseline next to the Cargo.toml for the perf
//! trajectory across PRs. `FEEL_BENCH_QUICK=1` cuts iterations for CI
//! smoke runs.

use std::time::Instant;

use feel::util::json::{num, obj, s, Json};
use feel::util::linalg::{gemm, gemm_at, gemm_bt, gemm_ref};
use feel::util::rng::Pcg;
use feel::util::threads;

fn filled(len: usize, seed: u64) -> Vec<f32> {
    let mut r = Pcg::seeded(seed);
    (0..len).map(|_| r.normal() as f32).collect()
}

/// Mean seconds per call over `iters` timed iterations (after 1 warmup).
fn time_it<F: FnMut()>(mut f: F, iters: usize) -> f64 {
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

/// Iteration count targeting a roughly constant measurement window.
fn iters_for(flops: usize, quick: bool) -> usize {
    let budget = if quick { 5e7 } else { 1e9 };
    ((budget / flops as f64) as usize).clamp(2, 200)
}

fn main() {
    let quick = std::env::var("FEEL_BENCH_QUICK").is_ok();
    // square sweep + the mini_dense/mini_res/mini_mobile layer shapes the
    // host oracle actually runs (batch 128)
    let shapes: &[(usize, usize, usize, &str)] = &[
        (64, 64, 64, "square"),
        (128, 128, 128, "square"),
        (256, 256, 256, "square"),
        (384, 384, 384, "square"),
        (512, 512, 512, "square"),
        (128, 588, 192, "mini_dense blk"),
        (128, 256, 256, "mini_res body"),
        (128, 384, 384, "mini_mobile body"),
    ];

    println!("\n== gemm (cores = {}) ==", threads::available());
    println!(
        "{:<24} {:>12} {:>12} {:>12} {:>9} {:>10}",
        "shape", "ref", "packed", "packed-mt", "speedup", "GFLOP/s"
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut speedup_256 = 0.0f64;
    for &(m, k, n, label) in shapes {
        let a = filled(m * k, 1);
        let b = filled(k * n, 2);
        let mut c = vec![0f32; m * n];
        let flops = 2 * m * k * n;
        let iters = iters_for(m * k * n, quick);

        let t_ref = time_it(
            || {
                c.iter_mut().for_each(|x| *x = 0.0);
                gemm_ref(m, k, n, &a, &b, &mut c);
                std::hint::black_box(&c);
            },
            iters,
        );
        let t_packed = time_it(
            || {
                c.iter_mut().for_each(|x| *x = 0.0);
                threads::with_budget(1, || gemm(m, k, n, &a, &b, &mut c));
                std::hint::black_box(&c);
            },
            iters,
        );
        let t_mt = time_it(
            || {
                c.iter_mut().for_each(|x| *x = 0.0);
                gemm(m, k, n, &a, &b, &mut c);
                std::hint::black_box(&c);
            },
            iters,
        );
        let speedup = t_ref / t_packed;
        if (m, k, n) == (256, 256, 256) {
            speedup_256 = speedup;
        }
        let gflops = flops as f64 / t_packed / 1e9;
        println!(
            "{:<24} {:>10.2}ms {:>10.2}ms {:>10.2}ms {:>8.2}x {:>10.2}",
            format!("{m}x{k}x{n} {label}"),
            t_ref * 1e3,
            t_packed * 1e3,
            t_mt * 1e3,
            speedup,
            gflops,
        );
        rows.push(obj(vec![
            ("op", Json::Str("gemm".into())),
            ("label", s(label)),
            ("m", num(m as f64)),
            ("k", num(k as f64)),
            ("n", num(n as f64)),
            ("ref_ms", num(t_ref * 1e3)),
            ("packed_ms", num(t_packed * 1e3)),
            ("packed_mt_ms", num(t_mt * 1e3)),
            ("speedup_vs_ref", num(speedup)),
            ("gflops_serial", num(gflops)),
        ]));
    }

    // the two transposed orientations at the acceptance size (serial)
    let (m, k, n) = (256usize, 256usize, 256usize);
    let a = filled(m * k, 3);
    let d = filled(m * n, 4);
    let b = filled(k * n, 5);
    let iters = iters_for(m * k * n, quick);
    let mut c_at = vec![0f32; k * n];
    let t_at = time_it(
        || {
            c_at.iter_mut().for_each(|x| *x = 0.0);
            threads::with_budget(1, || gemm_at(m, k, n, &a, &d, &mut c_at));
            std::hint::black_box(&c_at);
        },
        iters,
    );
    let mut c_bt = vec![0f32; m * k];
    let t_bt = time_it(
        || {
            c_bt.iter_mut().for_each(|x| *x = 0.0);
            threads::with_budget(1, || gemm_bt(m, k, n, &d, &b, &mut c_bt));
            std::hint::black_box(&c_bt);
        },
        iters,
    );
    let flops = 2.0 * (m * k * n) as f64;
    println!(
        "{:<24} {:>23} {:>12} {:>9} {:>10.2}",
        "256^3 gemm_at (x^T dy)",
        "",
        format!("{:.2}ms", t_at * 1e3),
        "",
        flops / t_at / 1e9
    );
    println!(
        "{:<24} {:>23} {:>12} {:>9} {:>10.2}",
        "256^3 gemm_bt (dy W^T)",
        "",
        format!("{:.2}ms", t_bt * 1e3),
        "",
        flops / t_bt / 1e9
    );
    rows.push(obj(vec![
        ("op", Json::Str("gemm_at".into())),
        ("m", num(m as f64)),
        ("k", num(k as f64)),
        ("n", num(n as f64)),
        ("packed_ms", num(t_at * 1e3)),
        ("gflops_serial", num(flops / t_at / 1e9)),
    ]));
    rows.push(obj(vec![
        ("op", Json::Str("gemm_bt".into())),
        ("m", num(m as f64)),
        ("k", num(k as f64)),
        ("n", num(n as f64)),
        ("packed_ms", num(t_bt * 1e3)),
        ("gflops_serial", num(flops / t_bt / 1e9)),
    ]));

    let out = obj(vec![
        ("bench", s("gemm")),
        ("cores", num(threads::available() as f64)),
        ("quick", Json::Bool(quick)),
        ("speedup_256_vs_ref", num(speedup_256)),
        ("results", Json::Arr(rows)),
    ]);
    let path = "BENCH_gemm.json";
    match std::fs::write(path, format!("{out}\n")) {
        Ok(()) => println!("\nbaseline -> {path} (256^3 speedup {speedup_256:.2}x vs ref)"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
