//! Hierarchical-topology bench: a C × tau sweep at K = 120. Each cell is
//! an edge server on an even share of the band running the proposed
//! per-period optimization over its own device slice; the cloud
//! FedAvg-merges the edge models every tau edge rounds. The sweep tracks
//! what the topology buys and costs on the *simulated* time axis (cells
//! barrier on the slowest cell at every cloud round) next to the learning
//! outcome, so later PRs (cross-cell interference, handover, client
//! sampling) have a baseline to move.
//!
//! Built through the config layer (`topology.cells` / `topology.tau` →
//! `run_hier_scheme`), so this bench smoke-tests the exact path
//! `feel train --cells C --tau N` takes. Emits a `BENCH_hier.json`
//! baseline next to the Cargo.toml, beside the other `BENCH_*.json`
//! files.

#![allow(clippy::field_reassign_with_default)]

use std::time::Instant;

use feel::config::Experiment;
use feel::coordinator::Scheme;
use feel::exp::common::{run_hier_scheme, BackendKind};
use feel::util::json::{num, obj, s, Json};

const K: usize = 120;
const DIM: usize = 16;

struct Run {
    sim_secs_per_period: f64,
    final_loss: f64,
    cloud_rounds: usize,
    wall_secs: f64,
}

fn run(cells: usize, tau: usize, periods: usize) -> Run {
    let mut exp = Experiment::default();
    exp.k = K;
    exp.model = "mini_res".into();
    exp.synth.dim = DIM;
    exp.train_n = 16 * K;
    exp.test_n = 128;
    exp.cells = cells;
    exp.tau = tau;
    exp.trainer.b_max = 16;
    exp.trainer.eval_every = 0;
    exp.trainer.scheme = Scheme::Proposed;
    let t0 = Instant::now();
    let out = run_hier_scheme(&exp, Scheme::Proposed, BackendKind::Host, periods, 0).unwrap();
    Run {
        // the hierarchy makespan (slowest cell after the final barrier),
        // not the merged log's last record — the speedup column depends
        // on comparing like with like across C
        sim_secs_per_period: out.sim_time / periods.max(1) as f64,
        final_loss: out.log.final_loss().unwrap_or(f64::NAN),
        cloud_rounds: out.cloud_rounds,
        wall_secs: t0.elapsed().as_secs_f64(),
    }
}

fn main() {
    let quick = std::env::var("FEEL_BENCH_QUICK").is_ok();
    let periods = if quick { 4 } else { 12 };
    let cells_sweep: &[usize] = if quick { &[1, 3] } else { &[1, 3, 6] };
    let taus: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };

    println!("\n== hierarchical topology (K = {K}, {periods} periods) ==");
    println!(
        "{:>6} {:>5} {:>14} {:>10} {:>12} {:>10}",
        "cells", "tau", "sim s/period", "vs flat", "cloud rounds", "loss"
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut flat_spp = f64::NAN;
    for &cells in cells_sweep {
        for &tau in taus {
            if cells == 1 && tau != 1 {
                continue; // tau is a no-op without a second cell
            }
            let r = run(cells, tau, periods);
            if cells == 1 && tau == 1 {
                flat_spp = r.sim_secs_per_period;
            }
            println!(
                "{:>6} {:>5} {:>14.4} {:>9.2}x {:>12} {:>10.4}",
                cells,
                tau,
                r.sim_secs_per_period,
                flat_spp / r.sim_secs_per_period,
                r.cloud_rounds,
                r.final_loss
            );
            rows.push(obj(vec![
                ("cells", num(cells as f64)),
                ("tau", num(tau as f64)),
                ("sim_secs_per_period", num(r.sim_secs_per_period)),
                ("speedup_vs_flat", num(flat_spp / r.sim_secs_per_period)),
                ("cloud_rounds", num(r.cloud_rounds as f64)),
                ("final_train_loss", num(r.final_loss)),
                ("wall_secs", num(r.wall_secs)),
            ]));
        }
    }

    let out = obj(vec![
        ("bench", s("hier")),
        ("scheme", s("proposed")),
        ("model", s("mini_res")),
        ("k", num(K as f64)),
        ("dim", num(DIM as f64)),
        ("quick", Json::Bool(quick)),
        ("periods", num(periods as f64)),
        ("results", Json::Arr(rows)),
    ]);
    let path = "BENCH_hier.json";
    match std::fs::write(path, format!("{out}\n")) {
        Ok(()) => println!("\nbaseline -> {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
