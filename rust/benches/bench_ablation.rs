//! Ablation harness (DESIGN.md §6): where does the proposed scheme's win
//! come from? Decouples the two halves of the joint policy and checks the
//! dL = xi*sqrt(B) model against a dense efficiency scan.
//!
//! Prints efficiency (learning-efficiency units, higher = better) for:
//!   joint (Theorem 1)  |  opt-B + equal slots  |  equal-B + opt slots  |
//!   equal-B + equal slots — plus the E(B) scan the golden section climbs.

use feel::benchkit::Bench;
use feel::opt::baselines::{solve_equal_slots, solve_fixed_batches};
use feel::opt::global::{efficiency_scan, solve};
use feel::opt::types::{DeviceInst, Instance};
use feel::opt::uplink::makespan_fixed_slots;
use feel::util::rng::Pcg;

fn instance(k: usize, seed: u64) -> Instance {
    let mut rng = Pcg::seeded(seed);
    let devices = (0..k)
        .map(|_| DeviceInst {
            speed: rng.range_f64(10.0, 80.0),
            offset: 0.0,
            b_min: 1.0,
            b_max: 128.0,
            rate_ul: rng.range_f64(2e6, 40e6),
            rate_dl: rng.range_f64(4e6, 80e6),
            update_lat: rng.range_f64(0.005, 0.05),
        })
        .collect();
    Instance { devices, s_bits: 182_400.0, frame_ul: 0.01, frame_dl: 0.01, xi: 0.05 }
}

fn main() {
    let mut b = Bench::new("ablation");
    b.header();

    let inst = instance(12, 7);
    let joint = solve(&inst, 1e-9).unwrap();
    let b_star = joint.solution.b_total;

    // optimal B, equal slots
    let equal_b: Vec<f64> = vec![b_star / 12.0; 12];
    let opt_b = joint.solution.batches.clone();
    let eq_slots_opt_b = solve_equal_slots(&inst, &opt_b);
    let opt_slots_eq_b = solve_fixed_batches(&inst, &equal_b, 1e-9).unwrap();
    let eq_eq = solve_equal_slots(&inst, &equal_b);

    println!("\n  ablation at K=12 (learning efficiency, higher is better):");
    println!("    joint (Theorem 1):        {:.5}", joint.efficiency);
    println!("    opt B  + equal slots:     {:.5}", eq_slots_opt_b.efficiency(inst.xi));
    println!("    equal B + opt slots:      {:.5}", opt_slots_eq_b.efficiency(inst.xi));
    println!("    equal B + equal slots:    {:.5}", eq_eq.efficiency(inst.xi));

    // sanity: fixed-slot makespan recomputation agrees with the Solution
    let t = makespan_fixed_slots(&inst, &opt_b, &eq_slots_opt_b.tau_ul);
    assert!((t - eq_slots_opt_b.t_up).abs() < 1e-9);

    // dense scan: unimodality evidence for the golden-section outer loop
    let scan = efficiency_scan(&inst, 60, 1e-9).unwrap();
    let best = scan.iter().cloned().fold((0.0, f64::NEG_INFINITY), |a, x| {
        if x.1 > a.1 { x } else { a }
    });
    println!(
        "    E(B) scan max: E={:.5} at B={:.0} (golden-section found B*={:.0})",
        best.1, best.0, b_star
    );

    b.bench("efficiency_scan_60pts_k12", || {
        std::hint::black_box(efficiency_scan(&inst, 60, 1e-6).unwrap());
    });
    b.bench("joint_solve_k12", || {
        std::hint::black_box(solve(&inst, 1e-6).unwrap());
    });
}
