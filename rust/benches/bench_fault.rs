//! Fault-injection bench: what does robustness cost, and what does it
//! buy? Two sweeps over the host backend:
//!
//! 1. The headline corruption matrix — 10% NaN-corrupted payloads under
//!    every quarantine policy. `off` accepts the poison and the loss
//!    diverges to NaN; `reject` and `clip` finish finite and keep
//!    learning. The same claim `tests/fault_injection.rs` pins.
//! 2. The full fault stack (crash windows + corruption + reject
//!    quarantine + stragglers) under sync, deadline, and async round
//!    policies — per-round wall overhead vs the clean run.
//!
//! Emits `BENCH_fault.json` beside the Cargo.toml like the other
//! `BENCH_*.json` baselines. `FEEL_BENCH_QUICK=1` shrinks the sweep for
//! CI smoke runs.

use std::time::Instant;

use feel::coordinator::{HostBackend, Trainer, TrainerConfig};
use feel::data::{generate, Partition, SynthConfig};
use feel::device::{paper_cpu_fleet, StragglerModel};
use feel::fault::FaultPlan;
use feel::grad::{GradGuard, Quarantine};
use feel::sched::RoundPolicy;
use feel::util::json::{num, obj, s, Json};
use feel::util::rng::Pcg;
use feel::wireless::CellConfig;

const SEED: u64 = 42;

struct RunStats {
    final_loss: f64,
    crashed: usize,
    corrupt: usize,
    quarantined: usize,
    ms_per_period: f64,
}

fn run_one(
    k: usize,
    periods: usize,
    policy: RoundPolicy,
    straggler: StragglerModel,
    fault: FaultPlan,
    guard: GradGuard,
) -> RunStats {
    let cfg = SynthConfig { dim: 12, ..Default::default() };
    let train = generate(&cfg, 20 * k, 1);
    let test = generate(&cfg, 200, 1);
    let mut rng = Pcg::seeded(SEED);
    let fleet = paper_cpu_fleet(k, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
    let be = HostBackend::for_model("mini_dense", 12, 10, 3).unwrap();
    let tc = TrainerConfig {
        policy,
        straggler,
        fault,
        guard,
        b_max: 8,
        eval_every: 0,
        ..Default::default()
    };
    let mut tr = Trainer::new(tc, fleet, &train, &test, Partition::Iid, &be).unwrap();
    let t0 = Instant::now();
    tr.run(periods).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    RunStats {
        final_loss: tr.log.records.last().map(|r| r.train_loss).unwrap_or(f64::NAN),
        crashed: tr.log.records.iter().map(|r| r.crashed).sum(),
        corrupt: tr.log.records.iter().map(|r| r.corrupt).sum(),
        quarantined: tr.log.records.iter().map(|r| r.quarantined).sum(),
        ms_per_period: wall / periods as f64 * 1e3,
    }
}

fn loss_cell(loss: f64) -> Json {
    if loss.is_finite() {
        num(loss)
    } else {
        Json::Null
    }
}

fn main() {
    let quick = std::env::var("FEEL_BENCH_QUICK").is_ok();
    let (k, periods) = if quick { (12, 8) } else { (24, 16) };
    let mut rows: Vec<Json> = Vec::new();

    println!("\n== 10% NaN corruption x quarantine policy (K={k}, {periods} periods) ==");
    println!(
        "{:>8} {:>12} {:>9} {:>12} {:>10}",
        "policy", "final_loss", "corrupt", "quarantined", "ms/period"
    );
    let corrupt = FaultPlan::new(0.0, 1, 0.1, 0.0, 0.0).unwrap();
    for policy in [Quarantine::Off, Quarantine::Reject, Quarantine::Clip] {
        let guard = match policy {
            Quarantine::Off => GradGuard::off(),
            p => GradGuard::new(p, f64::INFINITY).unwrap(),
        };
        let st = run_one(k, periods, RoundPolicy::Sync, StragglerModel::none(), corrupt, guard);
        println!(
            "{:>8} {:>12.4} {:>9} {:>12} {:>10.2}",
            policy.name(),
            st.final_loss,
            st.corrupt,
            st.quarantined,
            st.ms_per_period
        );
        rows.push(obj(vec![
            ("sweep", s("corruption_matrix")),
            ("quarantine", s(policy.name())),
            ("corrupt_rate", num(0.1)),
            ("final_loss", loss_cell(st.final_loss)),
            ("finite", Json::Bool(st.final_loss.is_finite())),
            ("corrupt_total", num(st.corrupt as f64)),
            ("quarantined_total", num(st.quarantined as f64)),
            ("ms_per_period", num(st.ms_per_period)),
        ]));
    }

    println!("\n== full fault stack vs clean run, per round policy ==");
    println!(
        "{:>10} {:>9} {:>12} {:>9} {:>12} {:>10}",
        "policy", "faults", "final_loss", "crashed", "quarantined", "ms/period"
    );
    let stack = FaultPlan::new(0.1, 2, 0.05, 0.0, 0.0).unwrap();
    let sm = StragglerModel::new(0.5, 0.1).unwrap();
    for (name, policy) in [
        ("sync", RoundPolicy::Sync),
        ("deadline", RoundPolicy::Deadline { factor: 1.25 }),
        ("async", RoundPolicy::Async { alpha: 0.6, beta: 0.5, quorum: 0.5 }),
    ] {
        for (faulty, fault, guard) in [
            (false, FaultPlan::none(), GradGuard::off()),
            (true, stack, GradGuard::new(Quarantine::Reject, f64::INFINITY).unwrap()),
        ] {
            let st = run_one(k, periods, policy, sm, fault, guard);
            println!(
                "{:>10} {:>9} {:>12.4} {:>9} {:>12} {:>10.2}",
                name, faulty, st.final_loss, st.crashed, st.quarantined, st.ms_per_period
            );
            rows.push(obj(vec![
                ("sweep", s("fault_stack")),
                ("policy", s(name)),
                ("faults", Json::Bool(faulty)),
                ("final_loss", loss_cell(st.final_loss)),
                ("finite", Json::Bool(st.final_loss.is_finite())),
                ("crashed_total", num(st.crashed as f64)),
                ("corrupt_total", num(st.corrupt as f64)),
                ("quarantined_total", num(st.quarantined as f64)),
                ("ms_per_period", num(st.ms_per_period)),
            ]));
        }
    }

    let out = obj(vec![
        ("bench", s("fault")),
        ("quick", Json::Bool(quick)),
        ("k", num(k as f64)),
        ("periods", num(periods as f64)),
        ("seed", num(SEED as f64)),
        ("results", Json::Arr(rows)),
    ]);
    let path = "BENCH_fault.json";
    match std::fs::write(path, format!("{out}\n")) {
        Ok(()) => println!("\nbaseline -> {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
