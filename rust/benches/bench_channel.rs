//! Wireless-substrate benches: closed-form ergodic rate (E1) vs Monte
//! Carlo, link stepping, GPU latency fitting — the per-period planning
//! costs that precede every optimizer call (Fig. 2 + eq. 5/6 machinery).

use feel::benchkit::Bench;
use feel::device::paper_profiles;
use feel::util::rng::Pcg;
use feel::util::stats::fit_piecewise;
use feel::wireless::rate::{ergodic_rate, monte_carlo_rate};
use feel::wireless::{CellConfig, DeviceLink};

fn main() {
    let mut b = Bench::new("channel");
    b.header();

    b.bench("ergodic_rate_closed_form", || {
        for gamma in [0.3, 3.0, 30.0, 300.0] {
            std::hint::black_box(ergodic_rate(10e6, gamma));
        }
    });

    let mut rng = Pcg::seeded(1);
    b.bench("ergodic_rate_monte_carlo_10k", || {
        std::hint::black_box(monte_carlo_rate(10e6, 30.0, 10_000, &mut rng));
    });

    let mut rng2 = Pcg::seeded(2);
    let mut links: Vec<DeviceLink> = (0..12)
        .map(|_| DeviceLink::sample(CellConfig::default(), 8.0, 0.7, &mut rng2))
        .collect();
    b.bench("link_step_k12", || {
        for l in links.iter_mut() {
            std::hint::black_box(l.step(&mut rng2));
        }
    });

    // Fig. 2's fit on 128-point sweeps
    let (_, gpu) = paper_profiles().remove(0);
    let bs: Vec<f64> = (1..=128).map(|x| x as f64).collect();
    let mut rng3 = Pcg::seeded(3);
    let ts: Vec<f64> = bs.iter().map(|&x| gpu.measure(x, 0.02, &mut rng3)).collect();
    b.bench("gpu_piecewise_fit_128pts", || {
        std::hint::black_box(fit_piecewise(&bs, &ts));
    });

    // accuracy cross-check printed for the record
    let cf = ergodic_rate(10e6, 30.0);
    let mc = monte_carlo_rate(10e6, 30.0, 1_000_000, &mut rng);
    println!(
        "\n  closed form {cf:.1} bit/s vs MC(1e6) {mc:.1} bit/s (diff {:.4}%)",
        100.0 * (cf - mc).abs() / cf
    );
}
