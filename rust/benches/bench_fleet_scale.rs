//! Fleet-scaling bench: whole-period throughput (periods/sec) of the
//! Proposed scheme vs worker-thread count at K = 4 / 16 / 64 devices, on
//! the host backend. This is the headline number for the parallel
//! device-execution engine — the per-device train/compress work dominates a
//! period at large K, so periods/sec should scale with threads until the
//! coordinator-side solve/aggregate serial fraction bites.
//!
//! Emits a `BENCH_fleet.json` baseline next to the Cargo.toml for the perf
//! trajectory across PRs.

#![allow(clippy::field_reassign_with_default)]

use std::time::Instant;

use feel::config::Experiment;
use feel::coordinator::{HostBackend, Scheme, Trainer};
use feel::data::{generate, Partition};
use feel::util::json::{num, obj, s, Json};
use feel::util::rng::Pcg;
use feel::util::threads;

const DIM: usize = 32;

/// (periods/sec, serial fraction): throughput plus how much of the period
/// wall time the coordinator's serial sections (solver + shard combine +
/// apply_update) consumed — the ROADMAP "perf trajectory" pair.
fn periods_per_sec(k: usize, worker_threads: usize, measure_periods: usize) -> (f64, f64) {
    let mut exp = Experiment::default();
    exp.k = k;
    exp.synth.dim = DIM;
    exp.train_n = 192 * k;
    exp.test_n = 128;
    let train = generate(&exp.synth, exp.train_n, 1);
    let test = generate(&exp.synth, exp.test_n, 1);
    let be = HostBackend::for_model("mini_res", DIM, exp.synth.classes, 1).unwrap();
    let mut cfg = exp.trainer.clone();
    cfg.scheme = Scheme::Proposed;
    cfg.eval_every = 0;
    cfg.threads = worker_threads;
    let mut rng = Pcg::seeded(3);
    let fleet = exp.fleet(&mut rng);
    let mut tr = Trainer::new(cfg, fleet, &train, &test, Partition::Iid, &be).unwrap();
    tr.step_period().unwrap(); // warmup (allocators, workspace pools, page faults)
    let warm = tr.log.wall;
    let t0 = Instant::now();
    tr.run(measure_periods).unwrap();
    let pps = measure_periods as f64 / t0.elapsed().as_secs_f64();
    // serial fraction over the measured periods only (subtract warmup)
    let serial = (tr.log.wall.solver_secs + tr.log.wall.reduce_secs)
        - (warm.solver_secs + warm.reduce_secs);
    let total = tr.log.wall.total_secs - warm.total_secs;
    (pps, if total > 0.0 { serial / total } else { 0.0 })
}

fn main() {
    let quick = std::env::var("FEEL_BENCH_QUICK").is_ok();
    let measure_periods = if quick { 2 } else { 4 };
    let cores = threads::available();
    let mut counts = vec![1usize, 2];
    if cores > 2 {
        counts.push(cores);
    }
    println!("\n== fleet_scale (cores = {cores}) ==");
    println!(
        "{:<10} {:>8} {:>16} {:>10} {:>10}",
        "config", "threads", "periods/sec", "speedup", "serial"
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut speedup_k64 = 1.0f64;
    for &k in &[4usize, 16, 64] {
        let mut base = 0.0f64;
        for &t in &counts {
            let (pps, serial_fraction) = periods_per_sec(k, t, measure_periods);
            if t == 1 {
                base = pps;
            }
            let speedup = pps / base;
            if k == 64 {
                speedup_k64 = speedup_k64.max(speedup);
            }
            println!(
                "{:<10} {:>8} {:>16.3} {:>9.2}x {:>9.1}%",
                format!("k{k}"),
                t,
                pps,
                speedup,
                serial_fraction * 100.0
            );
            rows.push(obj(vec![
                ("k", num(k as f64)),
                ("threads", num(t as f64)),
                ("periods_per_sec", num(pps)),
                ("speedup_vs_1t", num(speedup)),
                ("serial_fraction", num(serial_fraction)),
            ]));
        }
    }

    let out = obj(vec![
        ("bench", s("fleet_scale")),
        ("scheme", s("proposed")),
        ("model", s("mini_res")),
        ("dim", num(DIM as f64)),
        ("cores", num(cores as f64)),
        ("quick", Json::Bool(quick)),
        ("measure_periods", num(measure_periods as f64)),
        ("best_speedup_k64", num(speedup_k64)),
        ("results", Json::Arr(rows)),
    ]);
    let path = "BENCH_fleet.json";
    match std::fs::write(path, format!("{out}\n")) {
        Ok(()) => println!("\nbaseline -> {path} (best k=64 speedup {speedup_k64:.2}x)"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
