//! Straggler-policy bench: simulated seconds per period for the three
//! round policies (sync / deadline / async) under a jittered fleet, swept
//! over dropout ∈ {0, 0.1, 0.3}. The headline number is the *simulated*
//! time axis — the whole point of the deadline/async policies is to cut
//! the barrier tail a straggler-heavy fleet inflicts on the sync scheme —
//! plus the participation and staleness the cut costs.
//!
//! Emits a `BENCH_straggler.json` baseline next to the Cargo.toml, beside
//! `BENCH_fleet.json` / `BENCH_gemm.json`, for the perf trajectory across
//! PRs.

#![allow(clippy::field_reassign_with_default)]

use std::time::Instant;

use feel::config::Experiment;
use feel::coordinator::{HostBackend, Scheme, TrainLog, Trainer};
use feel::data::{generate, Partition};
use feel::device::StragglerModel;
use feel::sched::RoundPolicy;
use feel::util::json::{num, obj, s, Json};
use feel::util::rng::Pcg;

const DIM: usize = 32;
const K: usize = 12;
const JITTER: f64 = 0.5;

struct Run {
    log: TrainLog,
    wall_secs: f64,
}

fn run(policy: RoundPolicy, dropout: f64, periods: usize) -> Run {
    let mut exp = Experiment::default();
    exp.k = K;
    exp.synth.dim = DIM;
    exp.train_n = 96 * K;
    exp.test_n = 128;
    let train = generate(&exp.synth, exp.train_n, 1);
    let test = generate(&exp.synth, exp.test_n, 1);
    let be = HostBackend::for_model("mini_res", DIM, exp.synth.classes, 1).unwrap();
    let mut cfg = exp.trainer.clone();
    cfg.scheme = Scheme::Proposed;
    cfg.eval_every = 0;
    cfg.policy = policy;
    cfg.straggler = StragglerModel::new(JITTER, dropout).unwrap();
    let mut rng = Pcg::seeded(3);
    let fleet = exp.fleet(&mut rng);
    let mut tr = Trainer::new(cfg, fleet, &train, &test, Partition::Iid, &be).unwrap();
    let t0 = Instant::now();
    tr.run(periods).unwrap();
    Run { log: tr.log.clone(), wall_secs: t0.elapsed().as_secs_f64() }
}

fn main() {
    let quick = std::env::var("FEEL_BENCH_QUICK").is_ok();
    let periods = if quick { 4 } else { 12 };
    let policies: [(&str, RoundPolicy); 3] = [
        ("sync", RoundPolicy::Sync),
        ("deadline", RoundPolicy::Deadline { factor: 1.25 }),
        ("async", RoundPolicy::Async { alpha: 0.6, beta: 0.5, quorum: 0.5 }),
    ];
    let dropouts = [0.0f64, 0.1, 0.3];

    println!("\n== straggler policies (K = {K}, jitter = {JITTER}, {periods} periods) ==");
    println!(
        "{:<10} {:>8} {:>14} {:>10} {:>10} {:>10} {:>10}",
        "policy", "dropout", "sim s/period", "vs sync", "applied", "stale", "loss"
    );

    let mut rows: Vec<Json> = Vec::new();
    for &dropout in &dropouts {
        let mut sync_spp = f64::NAN;
        for (name, policy) in policies {
            let r = run(policy, dropout, periods);
            let n = r.log.records.len().max(1) as f64;
            let spp = r.log.sim_time() / n;
            if name == "sync" {
                sync_spp = spp;
            }
            let applied: f64 = r.log.records.iter().map(|x| x.applied as f64).sum::<f64>() / n;
            let stale: f64 = r.log.records.iter().map(|x| x.stale_mean).sum::<f64>() / n;
            let loss = r.log.final_loss().unwrap_or(f64::NAN);
            println!(
                "{:<10} {:>8} {:>14.4} {:>9.2}x {:>10.2} {:>10.3} {:>10.4}",
                name,
                dropout,
                spp,
                sync_spp / spp,
                applied,
                stale,
                loss
            );
            rows.push(obj(vec![
                ("policy", s(name)),
                ("dropout", num(dropout)),
                ("jitter", num(JITTER)),
                ("sim_secs_per_period", num(spp)),
                ("speedup_vs_sync", num(sync_spp / spp)),
                ("mean_applied", num(applied)),
                ("mean_staleness", num(stale)),
                ("final_train_loss", num(loss)),
                ("wall_secs", num(r.wall_secs)),
            ]));
        }
    }

    let out = obj(vec![
        ("bench", s("straggler")),
        ("scheme", s("proposed")),
        ("model", s("mini_res")),
        ("k", num(K as f64)),
        ("dim", num(DIM as f64)),
        ("jitter", num(JITTER)),
        ("quick", Json::Bool(quick)),
        ("periods", num(periods as f64)),
        ("results", Json::Arr(rows)),
    ]);
    let path = "BENCH_straggler.json";
    match std::fs::write(path, format!("{out}\n")) {
        Ok(()) => println!("\nbaseline -> {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
