//! Heterogeneous-fleet bench: whole-period throughput of a two-tier
//! mixed fleet (tier-0 devices on `mini_dense`, tiers 1/2 on `mini_res`)
//! against the homogeneous `mini_res` baseline, across the three round
//! policies. Routing small devices to a small model family is the
//! whole point of multi-backend fleets — the mixed run should close
//! periods faster in wall time than an all-large fleet while both model
//! families keep learning.
//!
//! Built through the config layer (`fleet.backends` rules →
//! `make_fleet_backends`), so this bench also smoke-tests the exact path
//! `feel train --backends ...` takes. Emits a `BENCH_mixed.json`
//! baseline next to the Cargo.toml, beside the other `BENCH_*.json`
//! files, for the perf trajectory across PRs.

#![allow(clippy::field_reassign_with_default)]

use std::time::Instant;

use feel::config::{Experiment, TierBackend};
use feel::coordinator::{Scheme, TrainLog, Trainer};
use feel::data::{generate, Partition};
use feel::device::StragglerModel;
use feel::exp::common::{make_fleet_backends, BackendKind};
use feel::sched::RoundPolicy;
use feel::util::json::{num, obj, s, Json};
use feel::util::rng::Pcg;

const DIM: usize = 32;
const K: usize = 12;
const JITTER: f64 = 0.3;

struct Run {
    log: TrainLog,
    wall_secs: f64,
    families: usize,
}

fn run(mixed: bool, policy: RoundPolicy, periods: usize) -> Run {
    let mut exp = Experiment::default();
    exp.k = K;
    exp.model = "mini_res".into();
    exp.synth.dim = DIM;
    exp.train_n = 96 * K;
    exp.test_n = 128;
    if mixed {
        exp.backends = vec![TierBackend {
            tier: 0,
            model: "mini_dense".into(),
            backend: None,
        }];
    }
    exp.trainer.scheme = Scheme::Proposed;
    exp.trainer.eval_every = 0;
    exp.trainer.policy = policy;
    exp.trainer.straggler = StragglerModel::new(JITTER, 0.0).unwrap();
    let backends = make_fleet_backends(&exp, BackendKind::Host).unwrap();
    let train = generate(&exp.synth, exp.train_n, 1);
    let test = generate(&exp.synth, exp.test_n, 1);
    let mut rng = Pcg::seeded(3);
    let fleet = exp.fleet(&mut rng);
    let mut tr = Trainer::with_backends(
        exp.trainer.clone(),
        fleet,
        &train,
        &test,
        Partition::Iid,
        backends.set(),
    )
    .unwrap();
    tr.step_period().unwrap(); // warmup (workspace pools, page faults)
    let t0 = Instant::now();
    tr.run(periods).unwrap();
    Run {
        log: tr.log.clone(),
        wall_secs: t0.elapsed().as_secs_f64(),
        families: backends.family_count(),
    }
}

fn main() {
    let quick = std::env::var("FEEL_BENCH_QUICK").is_ok();
    let periods = if quick { 3 } else { 10 };
    let policies: [(&str, RoundPolicy); 3] = [
        ("sync", RoundPolicy::Sync),
        ("deadline", RoundPolicy::Deadline { factor: 1.25 }),
        ("async", RoundPolicy::Async { alpha: 0.6, beta: 0.5, quorum: 0.5 }),
    ];

    println!("\n== mixed fleets (K = {K}, jitter = {JITTER}, {periods} periods) ==");
    println!(
        "{:<10} {:<14} {:>10} {:>14} {:>10} {:>10}",
        "policy", "fleet", "families", "periods/sec", "vs homog", "loss"
    );

    let mut rows: Vec<Json> = Vec::new();
    for (name, policy) in policies {
        let mut homog_pps = f64::NAN;
        for mixed in [false, true] {
            let r = run(mixed, policy, periods);
            let pps = periods as f64 / r.wall_secs;
            if !mixed {
                homog_pps = pps;
            }
            let fleet_name = if mixed { "dense+res" } else { "res-only" };
            let loss = r.log.final_loss().unwrap_or(f64::NAN);
            println!(
                "{:<10} {:<14} {:>10} {:>14.3} {:>9.2}x {:>10.4}",
                name,
                fleet_name,
                r.families,
                pps,
                pps / homog_pps,
                loss
            );
            rows.push(obj(vec![
                ("policy", s(name)),
                ("fleet", s(fleet_name)),
                ("families", num(r.families as f64)),
                ("periods_per_sec", num(pps)),
                ("speedup_vs_homogeneous", num(pps / homog_pps)),
                ("final_train_loss", num(loss)),
                ("sim_secs_per_period", num(r.log.sim_time() / r.log.records.len().max(1) as f64)),
                ("wall_secs", num(r.wall_secs)),
            ]));
        }
    }

    let out = obj(vec![
        ("bench", s("mixed_fleet")),
        ("scheme", s("proposed")),
        ("tier_rule", s("0:mini_dense (tiers 1-2: mini_res)")),
        ("k", num(K as f64)),
        ("dim", num(DIM as f64)),
        ("jitter", num(JITTER)),
        ("quick", Json::Bool(quick)),
        ("periods", num(periods as f64)),
        ("results", Json::Arr(rows)),
    ]);
    let path = "BENCH_mixed.json";
    match std::fs::write(path, format!("{out}\n")) {
        Ok(()) => println!("\nbaseline -> {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
