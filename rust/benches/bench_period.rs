//! End-to-end training-period benches (host backend): the L3 hot path a
//! coordination-bound deployment cares about — one full period under each
//! scheme — plus the aggregation/compression inner loops at real gradient
//! sizes. The table rows these throughputs feed are Table II (schemes) and
//! Fig. 4/5 (policies).

#![allow(clippy::field_reassign_with_default)]

use feel::benchkit::Bench;
use feel::compress::Sbc;
use feel::config::Experiment;
use feel::coordinator::{HostBackend, Scheme, Trainer};
use feel::data::{generate, Partition};
use feel::grad::Aggregator;
use feel::opt::BatchPolicy;
use feel::util::rng::Pcg;

fn main() {
    let mut b = Bench::new("period");
    b.header();

    // full periods under each scheme (small model = coordination visible)
    let mut exp = Experiment::default();
    exp.synth.dim = 48;
    exp.train_n = 1200;
    exp.test_n = 256;
    exp.k = 6;
    let train = generate(&exp.synth, exp.train_n, 1);
    let test = generate(&exp.synth, exp.test_n, 1);
    for (scheme, name) in [
        (Scheme::Proposed, "proposed"),
        (Scheme::Fixed { policy: BatchPolicy::Online, optimal_slots: true }, "online"),
        (Scheme::Fixed { policy: BatchPolicy::Full, optimal_slots: true }, "full_batch"),
    ] {
        let be = HostBackend::for_model("mini_res", 48, 10, 1).unwrap();
        let mut cfg = exp.trainer.clone();
        cfg.scheme = scheme;
        cfg.eval_every = 0;
        let mut rng = Pcg::seeded(3);
        let fleet = exp.fleet(&mut rng);
        let mut tr = Trainer::new(cfg, fleet, &train, &test, Partition::Iid, &be).unwrap();
        b.bench(&format!("one_period_{name}_k6"), || {
            tr.step_period().unwrap();
        });
    }

    // aggregation at the real mini_res size (570k params, K=12)
    let p = 570_000;
    let mut rng = Pcg::seeded(5);
    let grads: Vec<Vec<f32>> = (0..12)
        .map(|_| (0..p).map(|_| rng.normal() as f32).collect())
        .collect();
    b.bench("aggregate_12x570k", || {
        let mut agg = Aggregator::new(p);
        for g in &grads {
            agg.add(g, 64.0).unwrap();
        }
        std::hint::black_box(agg.finish().unwrap());
    });

    // SBC encode at paper ratio on the real gradient size
    let mut sbc = Sbc::new(0.005, p);
    let g = &grads[0];
    b.bench("sbc_encode_570k_r0.005", || {
        std::hint::black_box(sbc.encode(g));
    });
    let msg = sbc.encode(g);
    b.bench("sbc_decode_570k", || {
        std::hint::black_box(Sbc::decode(&msg));
    });
}
