//! Million-device scale bench: per-round client sampling over a lazy
//! columnar fleet. A `FleetSpec` holds O(1) state no matter what K says;
//! each round draws a Bernoulli(frac) participant set from the
//! counter-derived sampler (geometric skip-sampling, O(sampled) work),
//! materializes ONLY the sampled devices, steps their links on per-device
//! counter-derived streams, and solves the paper's joint batchsize + slot
//! allocation over the sampled sub-problem — the exact per-round work the
//! sampled trainer does, minus the gradient math that is already covered
//! by the other benches.
//!
//! The headline row: K = 1,000,000 at sample_frac = 1e-4 must land within
//! ~2x of the K = 100 full-participation round — the round cost is a
//! function of the SAMPLED count, not the fleet size. Emits
//! `BENCH_scale.json` next to the Cargo.toml, beside the other
//! `BENCH_*.json` baselines.

use std::time::Instant;

use feel::coordinator::TrainerConfig;
use feel::device::{ClientSampler, FleetSpec};
use feel::opt;
use feel::opt::types::Instance;
use feel::util::json::{num, obj, s, Json};
use feel::util::rng::Pcg;
use feel::wireless::{CellConfig, PeriodRates};

/// Stream tag for the bench's per-device link draws (participation-indexed
/// Gauss-Markov shadowing, like the sampled trainer's).
const LINK_TAG: u64 = 0xbe9c_11ab_ca5e_0001;

const SEED: u64 = 42;

struct RoundCost {
    sampled: usize,
    b_total: f64,
    efficiency: f64,
    wall_secs: f64,
}

/// One sampled round: draw the participant set, materialize it, step its
/// links, solve the allocation. Everything touched is O(sampled).
fn sampled_round(spec: &FleetSpec, frac: f64, period: u64) -> RoundCost {
    let tc = TrainerConfig::default();
    let s_bits = tc.wire_ratio * tc.quant_bits as f64 * 570_000.0;
    let t0 = Instant::now();
    let ids: Vec<usize> = if frac < 1.0 {
        ClientSampler::devices(SEED, frac).unwrap().sample(period, spec.k())
    } else {
        (0..spec.k()).collect()
    };
    let mut devices: Vec<_> = ids.iter().map(|&id| spec.materialize(id)).collect();
    let rates: Vec<PeriodRates> = devices
        .iter_mut()
        .map(|d| {
            let mut rng = Pcg::for_device(SEED ^ LINK_TAG, period, d.id as u64);
            d.link.step(&mut rng)
        })
        .collect();
    let inst = Instance::from_fleet(
        &devices,
        &rates,
        tc.b_max as f64,
        s_bits,
        tc.frame_ul,
        tc.frame_dl,
        tc.xi_init,
    )
    .unwrap();
    let sol = opt::solve(&inst, 1e-9).unwrap();
    RoundCost {
        sampled: ids.len(),
        // Horvitz-Thompson estimate of the full-fleet batch total: the
        // sampled sum reweighted by the inverse inclusion probability
        b_total: sol.solution.b_total / frac,
        efficiency: sol.efficiency,
        wall_secs: t0.elapsed().as_secs_f64(),
    }
}

fn main() {
    let quick = std::env::var("FEEL_BENCH_QUICK").is_ok();
    let rounds = if quick { 3 } else { 8 };
    // (K, sample_frac): ~100 sampled devices per round at every scale
    let sweep: &[(usize, f64)] = if quick {
        &[(100, 1.0), (10_000, 0.01), (1_000_000, 1e-4)]
    } else {
        &[(100, 1.0), (10_000, 0.01), (100_000, 1e-3), (1_000_000, 1e-4)]
    };

    println!("\n== O(sampled) rounds over a lazy fleet ({rounds} rounds each) ==");
    println!(
        "{:>9} {:>11} {:>9} {:>12} {:>12} {:>10}",
        "K", "frac", "sampled", "ms/round", "vs K=100", "B* (HT)"
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut base_ms = f64::NAN;
    for &(k, frac) in sweep {
        let spec = FleetSpec::cpu(k, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, SEED);
        let mut wall = 0f64;
        let mut sampled = 0usize;
        let mut b_total = 0f64;
        let mut eff = 0f64;
        for r in 0..rounds {
            let c = sampled_round(&spec, frac, r as u64);
            wall += c.wall_secs;
            sampled += c.sampled;
            b_total += c.b_total;
            eff += c.efficiency;
        }
        let ms = wall / rounds as f64 * 1e3;
        if k == 100 {
            base_ms = ms;
        }
        println!(
            "{:>9} {:>11} {:>9} {:>12.3} {:>11.2}x {:>10.0}",
            k,
            frac,
            sampled / rounds,
            ms,
            ms / base_ms,
            b_total / rounds as f64
        );
        rows.push(obj(vec![
            ("k", num(k as f64)),
            ("sample_frac", num(frac)),
            ("mean_sampled", num(sampled as f64 / rounds as f64)),
            ("ms_per_round", num(ms)),
            ("vs_k100_full", num(ms / base_ms)),
            ("ht_b_total", num(b_total / rounds as f64)),
            ("mean_efficiency", num(eff / rounds as f64)),
        ]));
    }

    let out = obj(vec![
        ("bench", s("scale")),
        ("quick", Json::Bool(quick)),
        ("rounds", num(rounds as f64)),
        ("seed", num(SEED as f64)),
        ("results", Json::Arr(rows)),
    ]);
    let path = "BENCH_scale.json";
    match std::fs::write(path, format!("{out}\n")) {
        Ok(()) => println!("\nbaseline -> {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
