//! Fault-injection invariants, end to end. Faults are drawn from their
//! own counter-derived PCG streams (tagged with CRASH/CORRUPT/OUTAGE
//! constants), so (1) a zero-rate plan is a bitwise no-op, (2) enabling
//! one fault class never shifts another class's draws, and (3) runs with
//! crashes, corruption, quarantine, stragglers, and sampling all active
//! stay bitwise thread-invariant. The headline robustness claim is
//! pinned too: at 10% payload corruption an unguarded run diverges to
//! NaN while `quarantine = reject` keeps training.

use feel::coordinator::{TrainLog, Trainer, TrainerConfig};
use feel::data::{generate, Partition, SynthConfig};
use feel::device::{paper_cpu_fleet, StragglerModel};
use feel::fault::FaultPlan;
use feel::grad::{GradGuard, Quarantine};
use feel::sched::RoundPolicy;
use feel::util::rng::Pcg;
use feel::wireless::CellConfig;

fn run_flat(
    policy: RoundPolicy,
    straggler: StragglerModel,
    sample_frac: f64,
    fault: FaultPlan,
    guard: GradGuard,
    threads: usize,
    periods: usize,
) -> TrainLog {
    let cfg = SynthConfig { dim: 24, ..Default::default() };
    let train = generate(&cfg, 800, 1);
    let test = generate(&cfg, 200, 1);
    let mut rng = Pcg::seeded(2);
    let fleet = paper_cpu_fleet(4, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
    let be = feel::coordinator::HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
    let tc = TrainerConfig {
        policy,
        straggler,
        sample_frac,
        fault,
        guard,
        threads,
        eval_every: 4,
        ..Default::default()
    };
    let mut tr = Trainer::new(tc, fleet, &train, &test, Partition::Iid, &be).unwrap();
    tr.run(periods).unwrap();
    tr.log.clone()
}

fn assert_logs_equal(a: &TrainLog, b: &TrainLog, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: period count");
    for (x, y) in a.records.iter().zip(&b.records) {
        let p = x.period;
        assert_eq!(x.period, y.period, "{label} p{p}");
        assert_eq!(x.b_total, y.b_total, "{label} p{p}: b_total");
        assert_eq!(
            x.train_loss.to_bits(),
            y.train_loss.to_bits(),
            "{label} p{p}: train_loss {} vs {}",
            x.train_loss,
            y.train_loss
        );
        assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "{label} p{p}: sim_time");
        assert_eq!(x.t_period.to_bits(), y.t_period.to_bits(), "{label} p{p}: t_period");
        assert_eq!(x.lr.to_bits(), y.lr.to_bits(), "{label} p{p}: lr");
        assert_eq!(
            x.test_loss.map(f64::to_bits),
            y.test_loss.map(f64::to_bits),
            "{label} p{p}: test_loss"
        );
        assert_eq!(x.applied, y.applied, "{label} p{p}: applied");
        assert_eq!(x.dropped, y.dropped, "{label} p{p}: dropped");
        assert_eq!(x.late, y.late, "{label} p{p}: late");
        assert_eq!(
            x.stale_mean.to_bits(),
            y.stale_mean.to_bits(),
            "{label} p{p}: stale_mean"
        );
        assert_eq!(x.crashed, y.crashed, "{label} p{p}: crashed");
        assert_eq!(x.corrupt, y.corrupt, "{label} p{p}: corrupt");
        assert_eq!(x.quarantined, y.quarantined, "{label} p{p}: quarantined");
    }
}

/// A plan with every rate at zero must never touch an RNG stream: the
/// run is bitwise the no-plan run under all three round policies, with
/// stragglers and client sampling active. An armed-but-idle quarantine
/// (reject, no norm bound, clean payloads) is pinned as a no-op too.
#[test]
fn zero_rate_fault_plan_is_bitwise_noop_all_policies() {
    let sm = StragglerModel::new(0.5, 0.1).unwrap();
    let zero = FaultPlan::new(0.0, 1, 0.0, 0.0, 0.0).unwrap();
    for policy in [
        RoundPolicy::Sync,
        RoundPolicy::Deadline { factor: 1.25 },
        RoundPolicy::Async { alpha: 0.6, beta: 0.5, quorum: 0.5 },
    ] {
        let base = run_flat(policy, sm, 0.5, FaultPlan::none(), GradGuard::off(), 1, 8);
        let zeroed = run_flat(policy, sm, 0.5, zero, GradGuard::off(), 1, 8);
        assert_logs_equal(&base, &zeroed, &format!("zero-rate {policy:?}"));
        let armed = GradGuard::new(Quarantine::Reject, f64::INFINITY).unwrap();
        let guarded = run_flat(policy, sm, 0.5, FaultPlan::none(), armed, 1, 8);
        assert_logs_equal(&base, &guarded, &format!("idle guard {policy:?}"));
    }
}

/// Each fault class draws from its own tagged stream: toggling the other
/// classes on or off cannot move a single draw. Verified over a
/// (period, device) grid against single-class plans, plus the cell-outage
/// grid, with every class confirmed to actually fire inside the grid.
#[test]
fn fault_streams_are_isolated_per_class() {
    let seed = 7u64;
    let both = FaultPlan::new(0.2, 2, 0.2, 1.0, 0.3).unwrap();
    let crash_only = FaultPlan::new(0.2, 2, 0.0, 0.0, 0.0).unwrap();
    let corrupt_only = FaultPlan::new(0.0, 1, 0.2, 1.0, 0.0).unwrap();
    let outage_only = FaultPlan::new(0.0, 1, 0.0, 0.0, 0.3).unwrap();
    for period in 0..64u64 {
        for device in 0..16u64 {
            assert_eq!(
                both.crash_state(seed, period, device),
                crash_only.crash_state(seed, period, device),
                "crash draw moved at ({period}, {device})"
            );
            assert_eq!(
                both.corrupts(seed, period, device),
                corrupt_only.corrupts(seed, period, device),
                "corrupt draw moved at ({period}, {device})"
            );
        }
    }
    for block in 0..64u64 {
        for cell in 0..8u64 {
            assert_eq!(
                both.cell_out(seed, block, cell),
                outage_only.cell_out(seed, block, cell),
                "outage draw moved at ({block}, {cell})"
            );
        }
    }
    // the equalities are not vacuous: every class fires inside the grid
    assert!((0..64u64).any(|p| (0..16u64).any(|d| both.is_down(seed, p, d))));
    assert!((0..64u64).any(|p| (0..16u64).any(|d| both.corrupts(seed, p, d).is_some())));
    assert!((0..64u64).any(|b| (0..8u64).any(|c| both.cell_out(seed, b, c))));
}

/// The full robustness stack — K = 200 with client sampling, stragglers,
/// crash windows, NaN corruption, and the reject quarantine all active —
/// keeps the engine's core invariant: bitwise-identical logs (including
/// the crashed/corrupt/quarantined columns) at 1, 2, and 8 threads.
#[test]
fn faulty_sampled_k200_identical_at_1_2_8_threads() {
    let k = 200;
    let run = |threads: usize| -> TrainLog {
        let cfg = SynthConfig { dim: 12, ..Default::default() };
        let train = generate(&cfg, 8 * k, 1);
        let test = generate(&cfg, 200, 1);
        let mut rng = Pcg::seeded(2);
        let fleet = paper_cpu_fleet(k, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
        let be = feel::coordinator::HostBackend::for_model("mini_dense", 12, 10, 3).unwrap();
        let tc = TrainerConfig {
            sample_frac: 0.25,
            straggler: StragglerModel::new(0.5, 0.1).unwrap(),
            fault: FaultPlan::new(0.1, 2, 0.05, 0.0, 0.0).unwrap(),
            guard: GradGuard::new(Quarantine::Reject, f64::INFINITY).unwrap(),
            threads,
            b_max: 8,
            eval_every: 0,
            ..Default::default()
        };
        let mut tr = Trainer::new(tc, fleet, &train, &test, Partition::Iid, &be).unwrap();
        tr.run(6).unwrap();
        tr.log.clone()
    };
    let base = run(1);
    for t in [2usize, 8] {
        let par = run(t);
        assert_logs_equal(&base, &par, &format!("faulty k200 t={t}"));
    }
    // every fault path actually fired, so the equality covers them all
    assert!(base.records.iter().any(|r| r.crashed > 0), "no crashes drawn");
    assert!(base.records.iter().any(|r| r.corrupt > 0), "no corruption drawn");
    assert!(base.records.iter().any(|r| r.quarantined > 0), "nothing quarantined");
    assert!(base.records.iter().any(|r| r.dropped > 0), "no straggler dropouts");
    assert!(base.records.iter().all(|r| r.train_loss.is_finite()));
}

/// The headline robustness claim from the issue: at 10% NaN corruption an
/// unguarded run accepts the poisoned payloads and diverges to NaN, while
/// the same run under `quarantine = reject` stays finite and keeps
/// learning. Both runs share the seed, so they see identical draws.
#[test]
fn quarantine_reject_survives_corruption_that_sinks_unguarded_run() {
    let k = 12;
    let run = |guard: GradGuard| -> TrainLog {
        let cfg = SynthConfig { dim: 12, ..Default::default() };
        let train = generate(&cfg, 20 * k, 1);
        let test = generate(&cfg, 200, 1);
        let mut rng = Pcg::seeded(2);
        let fleet = paper_cpu_fleet(k, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
        let be = feel::coordinator::HostBackend::for_model("mini_dense", 12, 10, 3).unwrap();
        let tc = TrainerConfig {
            fault: FaultPlan::new(0.0, 1, 0.1, 0.0, 0.0).unwrap(),
            guard,
            b_max: 8,
            eval_every: 0,
            ..Default::default()
        };
        let mut tr = Trainer::new(tc, fleet, &train, &test, Partition::Iid, &be).unwrap();
        tr.run(12).unwrap();
        tr.log.clone()
    };
    let unguarded = run(GradGuard::off());
    let last = unguarded.records.last().unwrap();
    assert!(
        !last.train_loss.is_finite(),
        "unguarded run stayed finite at {}",
        last.train_loss
    );
    // the acceptance was not silent: the corrupt column saw the payloads
    assert!(unguarded.records.iter().any(|r| r.corrupt > 0));
    assert!(unguarded.records.iter().all(|r| r.quarantined == 0));

    let guarded = run(GradGuard::new(Quarantine::Reject, f64::INFINITY).unwrap());
    for r in &guarded.records {
        assert!(r.train_loss.is_finite(), "p{}: guarded loss {}", r.period, r.train_loss);
    }
    let (first, final_) =
        (guarded.records[0].train_loss, guarded.records.last().unwrap().train_loss);
    assert!(final_ < first, "guarded run did not learn: {first} -> {final_}");
    // under reject every detected payload is quarantined, none applied
    let corrupt: usize = guarded.records.iter().map(|r| r.corrupt).sum();
    let quarantined: usize = guarded.records.iter().map(|r| r.quarantined).sum();
    assert!(corrupt > 0, "corruption never fired");
    assert_eq!(corrupt, quarantined);
}

/// Crash windows that empty out entire rounds must not wedge the
/// trainer: every period still logs a record, and a light crash rate
/// leaves the run learning through the churn.
#[test]
fn crash_heavy_rounds_survive_and_light_crash_still_learns() {
    let sm = StragglerModel::none();
    // heavy: most periods lose the whole 4-device fleet
    let heavy = FaultPlan::new(0.9, 2, 0.0, 0.0, 0.0).unwrap();
    let log = run_flat(RoundPolicy::Sync, sm, 1.0, heavy, GradGuard::off(), 1, 12);
    assert_eq!(log.records.len(), 12);
    assert!(log.records.iter().any(|r| r.crashed == 4), "no fully-crashed round");
    // light: crashes fire but training still makes progress
    let light = FaultPlan::new(0.15, 2, 0.0, 0.0, 0.0).unwrap();
    let log = run_flat(RoundPolicy::Sync, sm, 1.0, light, GradGuard::off(), 1, 16);
    assert!(log.records.iter().any(|r| r.crashed > 0), "no crashes drawn");
    let (first, last) =
        (log.records[0].train_loss, log.records.last().unwrap().train_loss);
    assert!(last < first, "light-crash run did not learn: {first} -> {last}");
}
