//! The observability contract (obs/): enabling the tracer + metrics
//! registry is invisible to the numerics, and the artifacts it produces
//! are deterministic.
//!
//! Two pins:
//!   1. Off-path zero cost: a run with `enable_obs()` produces a
//!      `TrainLog` bitwise-identical to a disabled run's, under every
//!      round policy, flat and hierarchical — tracing consumes no RNG
//!      draws and changes no floats.
//!   2. Trace determinism: events are stamped with *simulated* time and
//!      emitted in fixed device/cell order, never from wall clock or
//!      thread scheduling — so the exported Chrome trace JSON and the
//!      metrics JSONL are byte-identical at 1/2/8 worker threads.
//!
//! Plus the event-coverage pin: a K = 40 faulted run's trace carries the
//! crash/corrupt/quarantine events, and a faulted hierarchy's trace
//! carries cell_outage/cloud_merge, with trace counters agreeing with
//! the `TrainLog` fault columns.

use feel::coordinator::{BackendSet, HostBackend, TrainLog, Trainer, TrainerConfig};
use feel::data::{generate, Dataset, Partition, SynthConfig};
use feel::device::{paper_cpu_fleet, StragglerModel};
use feel::fault::FaultPlan;
use feel::grad::{GradGuard, Quarantine};
use feel::hier::{CellWorld, HierConfig, HierTrainer};
use feel::sched::RoundPolicy;
use feel::util::json::Json;
use feel::util::rng::Pcg;
use feel::wireless::CellConfig;

const POLICIES: [RoundPolicy; 3] = [
    RoundPolicy::Sync,
    RoundPolicy::Deadline { factor: 1.25 },
    RoundPolicy::Async { alpha: 0.6, beta: 0.5, quorum: 0.5 },
];

struct Run {
    log: TrainLog,
    trace: String,
    metrics: String,
    audit: String,
}

fn run_flat(
    k: usize,
    policy: RoundPolicy,
    fault: FaultPlan,
    guard: GradGuard,
    threads: usize,
    obs: bool,
    periods: usize,
) -> Run {
    let straggler = StragglerModel::new(0.5, 0.1).unwrap();
    run_flat_with(straggler, k, policy, fault, guard, threads, obs, periods)
}

#[allow(clippy::too_many_arguments)]
fn run_flat_with(
    straggler: StragglerModel,
    k: usize,
    policy: RoundPolicy,
    fault: FaultPlan,
    guard: GradGuard,
    threads: usize,
    obs: bool,
    periods: usize,
) -> Run {
    let cfg = SynthConfig { dim: 12, ..Default::default() };
    let train = generate(&cfg, 20 * k, 1);
    let test = generate(&cfg, 200, 1);
    let mut rng = Pcg::seeded(2);
    let fleet = paper_cpu_fleet(k, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
    let be = HostBackend::for_model("mini_res", 12, 10, 3).unwrap();
    let tc = TrainerConfig {
        policy,
        straggler,
        fault,
        guard,
        threads,
        b_max: 8,
        eval_every: 4,
        ..Default::default()
    };
    let mut tr = Trainer::new(tc, fleet, &train, &test, Partition::Iid, &be).unwrap();
    if obs {
        tr.enable_obs();
    }
    tr.run(periods).unwrap();
    Run {
        log: tr.log.clone(),
        trace: tr.export_trace(),
        metrics: tr.export_metrics(),
        audit: tr.export_audit(),
    }
}

/// Full-record bitwise equality, including the policy and fault columns.
fn assert_bitwise_equal(a: &TrainLog, b: &TrainLog, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: period count");
    for (x, y) in a.records.iter().zip(&b.records) {
        let p = x.period;
        assert_eq!(x.period, y.period, "{label} p{p}");
        assert_eq!(x.b_total, y.b_total, "{label} p{p}: b_total");
        assert_eq!(
            x.train_loss.to_bits(),
            y.train_loss.to_bits(),
            "{label} p{p}: train_loss"
        );
        assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "{label} p{p}: sim_time");
        assert_eq!(x.t_period.to_bits(), y.t_period.to_bits(), "{label} p{p}: t_period");
        assert_eq!(x.lr.to_bits(), y.lr.to_bits(), "{label} p{p}: lr");
        assert_eq!(
            x.efficiency.to_bits(),
            y.efficiency.to_bits(),
            "{label} p{p}: efficiency"
        );
        assert_eq!(
            x.test_loss.map(f64::to_bits),
            y.test_loss.map(f64::to_bits),
            "{label} p{p}: test_loss"
        );
        assert_eq!(
            x.test_acc.map(f64::to_bits),
            y.test_acc.map(f64::to_bits),
            "{label} p{p}: test_acc"
        );
        assert_eq!(x.applied, y.applied, "{label} p{p}: applied");
        assert_eq!(x.dropped, y.dropped, "{label} p{p}: dropped");
        assert_eq!(x.late, y.late, "{label} p{p}: late");
        assert_eq!(
            x.stale_mean.to_bits(),
            y.stale_mean.to_bits(),
            "{label} p{p}: stale_mean"
        );
        assert_eq!(x.cell, y.cell, "{label} p{p}: cell");
        assert_eq!(x.cloud, y.cloud, "{label} p{p}: cloud");
        assert_eq!(x.crashed, y.crashed, "{label} p{p}: crashed");
        assert_eq!(x.corrupt, y.corrupt, "{label} p{p}: corrupt");
        assert_eq!(x.quarantined, y.quarantined, "{label} p{p}: quarantined");
    }
}

#[test]
fn enabling_obs_never_changes_numerics_flat() {
    for policy in POLICIES {
        let off = run_flat(4, policy, FaultPlan::none(), GradGuard::off(), 1, false, 6);
        let on = run_flat(4, policy, FaultPlan::none(), GradGuard::off(), 1, true, 6);
        assert_bitwise_equal(&off.log, &on.log, &format!("obs on/off {policy:?}"));
        // the disabled run produced no artifacts, the enabled one did —
        // so the equality is not comparing two no-op runs
        assert!(off.metrics.is_empty(), "{policy:?}");
        assert!(!on.metrics.is_empty(), "{policy:?}");
        assert!(off.audit.is_empty(), "{policy:?}");
        assert!(!on.audit.is_empty(), "{policy:?}");
        assert!(on.trace.contains("\"round\""), "{policy:?}: no round spans");
    }
}

#[test]
fn trace_and_metrics_byte_identical_at_1_2_8_threads() {
    for policy in POLICIES {
        let base = run_flat(4, policy, FaultPlan::none(), GradGuard::off(), 1, true, 8);
        // non-vacuous: under sync/deadline every participant samples the
        // straggler stream, so the dropouts pinned by exec_determinism
        // fire here too; async masks busy devices, so pin its close
        // events instead
        if matches!(policy, RoundPolicy::Async { .. }) {
            assert!(base.trace.contains("\"quorum_close\""));
        } else {
            assert!(base.log.records.iter().any(|r| r.dropped > 0), "{policy:?}");
            assert!(base.trace.contains("\"drop\""), "{policy:?}");
        }
        for t in [2usize, 8] {
            let par = run_flat(4, policy, FaultPlan::none(), GradGuard::off(), t, true, 8);
            assert_eq!(base.trace, par.trace, "{policy:?} t={t}: trace drifted");
            assert_eq!(base.metrics, par.metrics, "{policy:?} t={t}: metrics drifted");
            assert_eq!(base.audit, par.audit, "{policy:?} t={t}: audit drifted");
        }
        // the artifact is well-formed JSON with the Chrome trace shape
        let v = Json::parse(&base.trace).unwrap();
        let events = v.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert!(!events.is_empty(), "{policy:?}");
        for line in base.metrics.lines() {
            Json::parse(line).unwrap();
        }
        for line in base.audit.lines() {
            Json::parse(line).unwrap();
        }
    }
}

#[test]
fn faulted_k40_trace_carries_crash_and_quarantine_events() {
    // crash windows + NaN payload corruption, quarantine set to reject:
    // all three fault columns light up at K = 40 within a few periods
    let fault = FaultPlan::new(0.1, 2, 0.2, 0.0, 0.0).unwrap();
    let guard = GradGuard::new(Quarantine::Reject, 50.0).unwrap();
    let run = run_flat(40, RoundPolicy::Sync, fault, guard, 0, true, 4);
    let crashed: usize = run.log.records.iter().map(|r| r.crashed).sum();
    let corrupt: usize = run.log.records.iter().map(|r| r.corrupt).sum();
    let quarantined: usize = run.log.records.iter().map(|r| r.quarantined).sum();
    assert!(crashed > 0, "no crash fired in 4 periods at K = 40");
    assert!(corrupt > 0, "no corruption fired");
    assert!(quarantined > 0, "the reject guard never quarantined");
    assert!(run.trace.contains("\"crash\""));
    assert!(run.trace.contains("\"corrupt\""));
    assert!(run.trace.contains("\"quarantine\""));
    assert!(run.trace.contains("\"non_finite\""));
    // the metric counters agree with the log's fault columns
    let last = run.metrics.lines().last().unwrap();
    let v = Json::parse(last).unwrap();
    let counter = |name: &str| v.get("counters").unwrap().get(name).unwrap().as_usize();
    assert_eq!(counter("fault.crashed"), Some(crashed));
    assert_eq!(counter("fault.corrupt"), Some(corrupt));
    assert_eq!(counter("agg.quarantined"), Some(quarantined));
    assert_eq!(counter("agg.quarantine_verdicts"), Some(quarantined));
}

fn hier_worlds<'a>(shards: &'a [Dataset], be: &'a HostBackend, k: usize) -> Vec<CellWorld<'a>> {
    let mut rng = Pcg::seeded(2);
    let cell_cfg = CellConfig::default().split_bandwidth(shards.len());
    shards
        .iter()
        .map(|train| CellWorld {
            fleet: paper_cpu_fleet(k, 7e7, 1e8, cell_cfg, 4.0, 0.5, &mut rng),
            backends: BackendSet::homogeneous(k, "mini_res", be),
            train,
        })
        .collect()
}

fn run_hier(outage: f64, threads: usize, obs: bool, periods: usize) -> Run {
    let cfg = SynthConfig { dim: 12, ..Default::default() };
    let shards: Vec<Dataset> = (0..3).map(|c| generate(&cfg, 160, c as u64 + 1)).collect();
    let test = generate(&cfg, 120, 9);
    let be = HostBackend::for_model("mini_res", 12, 10, 3).unwrap();
    let tc = TrainerConfig {
        threads,
        b_max: 8,
        eval_every: 0,
        straggler: StragglerModel::new(0.5, 0.1).unwrap(),
        fault: FaultPlan::new(0.0, 1, 0.0, 0.0, outage).unwrap(),
        ..Default::default()
    };
    let hc = HierConfig { tau: 2, ..Default::default() };
    let worlds = hier_worlds(&shards, &be, 2);
    let mut hier = HierTrainer::new(tc, hc, worlds, &test, Partition::Iid).unwrap();
    if obs {
        hier.enable_obs();
    }
    hier.run(periods).unwrap();
    Run {
        log: hier.merged_log(),
        trace: hier.export_trace(),
        metrics: hier.export_metrics(),
        audit: hier.export_audit(),
    }
}

#[test]
fn enabling_obs_never_changes_numerics_hier() {
    let off = run_hier(0.0, 1, false, 4);
    let on = run_hier(0.0, 1, true, 4);
    assert_bitwise_equal(&off.log, &on.log, "hier obs on/off");
    assert!(off.metrics.is_empty());
    assert!(on.trace.contains("\"cloud_merge\""));
}

#[test]
fn hier_trace_byte_identical_at_1_2_8_threads() {
    let base = run_hier(0.0, 1, true, 4);
    for t in [2usize, 8] {
        let par = run_hier(0.0, t, true, 4);
        assert_eq!(base.trace, par.trace, "t={t}: hier trace drifted");
        assert_eq!(base.metrics, par.metrics, "t={t}: hier metrics drifted");
        assert_eq!(base.audit, par.audit, "t={t}: hier audit drifted");
    }
    // the merged audit carries all three cell lanes plus cloud-merge rows
    // (4 periods / tau 2 = 2 blocks)
    let cloud_rows = base
        .audit
        .lines()
        .map(|l| Json::parse(l).unwrap())
        .filter(|v| v.get("kind").and_then(Json::as_str) == Some("cloud"))
        .count();
    assert_eq!(cloud_rows, 2);
    for c in 0..3usize {
        assert!(
            base.audit
                .lines()
                .map(|l| Json::parse(l).unwrap())
                .any(|v| v.get("cell").and_then(Json::as_usize) == Some(c)
                    && v.get("kind").and_then(Json::as_str) == Some("period")),
            "cell {c} missing from merged audit"
        );
    }
    // three cell lanes plus the cloud lane made it into the artifact
    let v = Json::parse(&base.trace).unwrap();
    let events = v.get("traceEvents").and_then(Json::as_arr).unwrap();
    assert!(!events.is_empty());
    assert!(base.trace.contains("\"cloud\""));
    assert!(base.trace.contains("cell 0") && base.trace.contains("cell 2"));
    // 2 cloud merges (4 periods / tau 2) on the cloud lane's counters
    let cloud = last_cloud_snapshot(&base.metrics, 3);
    assert_eq!(cloud.get("counters").unwrap().get("cloud.merges").unwrap().as_usize(), Some(2));
}

/// Latest snapshot line stamped with the cloud lane id (`cells.len()`).
/// `merge_snaps` orders by (period, cell) and the cloud snapshots at block
/// cadence, so the overall last line belongs to a *cell*, not the cloud.
fn last_cloud_snapshot(metrics: &str, cloud_lane: usize) -> Json {
    metrics
        .lines()
        .map(|l| Json::parse(l).unwrap())
        .rfind(|v| v.get("cell").and_then(Json::as_usize) == Some(cloud_lane))
        .expect("no cloud-lane snapshot in the metrics JSONL")
}

#[test]
fn zero_jitter_sync_realizes_the_prediction_exactly() {
    // with no jitter and no dropout, the sync scheduler's realized
    // arrivals are the plan's clamped nominal finish times bitwise —
    // predicted == realized, straggler regret exactly 1
    let quiet = StragglerModel::new(0.0, 0.0).unwrap();
    let run = run_flat_with(
        quiet,
        4,
        RoundPolicy::Sync,
        FaultPlan::none(),
        GradGuard::off(),
        1,
        true,
        6,
    );
    let mut devices = 0usize;
    for line in run.audit.lines() {
        let v = Json::parse(line).unwrap();
        for d in v.get("devices").and_then(Json::as_arr).unwrap() {
            devices += 1;
            assert_eq!(d.get("outcome").and_then(Json::as_str), Some("applied"), "{line}");
            let p = d.get("p_finish").and_then(Json::as_f64).unwrap();
            let r = d.get("r_finish").and_then(Json::as_f64).unwrap();
            assert_eq!(p.to_bits(), r.to_bits(), "predicted != realized in {line}");
            assert_eq!(d.get("staleness"), Some(&Json::Null), "{line}");
            assert_eq!(d.get("carry").and_then(Json::as_usize), Some(0), "{line}");
        }
    }
    assert_eq!(devices, 4 * 6, "every device holds a row every period");
    // and the report derives from it without complaint
    let report = feel::obs::summarize_audit_jsonl(&run.audit).unwrap();
    assert!(report.contains("regret"), "{report}");
}

#[test]
fn audit_jsonl_field_set_is_pinned() {
    // golden field-set pin: downstream tooling parses these exact keys —
    // adding or renaming one is a deliberate, test-visible change
    let run = run_flat(4, RoundPolicy::Sync, FaultPlan::none(), GradGuard::off(), 1, true, 2);
    let first = Json::parse(run.audit.lines().next().unwrap()).unwrap();
    let keys: Vec<&str> = first.as_obj().unwrap().keys().map(|k| k.as_str()).collect();
    assert_eq!(
        keys,
        vec![
            "applied",
            "b_total",
            "cell",
            "devices",
            "kind",
            "loss_dec",
            "p_efficiency",
            "p_t_down",
            "p_t_period",
            "p_t_up",
            "period",
            "r_duration",
            "t_start",
        ]
    );
    let device = first.get("devices").and_then(Json::as_arr).unwrap()[0].as_obj().unwrap();
    let dkeys: Vec<&str> = device.keys().map(|k| k.as_str()).collect();
    assert_eq!(
        dkeys,
        vec![
            "batch",
            "carry",
            "device",
            "outcome",
            "p_comm",
            "p_compute",
            "p_finish",
            "p_slot",
            "r_finish",
            "staleness",
        ]
    );
}

#[test]
fn resumed_run_marks_resume_and_never_duplicates_snapshots() {
    let cfg = SynthConfig { dim: 12, ..Default::default() };
    let train = generate(&cfg, 80, 1);
    let test = generate(&cfg, 100, 1);
    let be = HostBackend::for_model("mini_res", 12, 10, 3).unwrap();
    let tc = TrainerConfig { b_max: 8, eval_every: 0, ..Default::default() };
    let path = std::env::temp_dir().join(format!("feel_obs_resume_{}.ckpt", std::process::id()));
    let mut rng = Pcg::seeded(2);
    let fleet = paper_cpu_fleet(4, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
    let mut a = Trainer::new(tc.clone(), fleet, &train, &test, Partition::Iid, &be).unwrap();
    a.run(3).unwrap();
    a.save_checkpoint(&path).unwrap();
    let mut rng = Pcg::seeded(2);
    let fleet = paper_cpu_fleet(4, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
    let mut b = Trainer::new(tc, fleet, &train, &test, Partition::Iid, &be).unwrap();
    b.enable_obs();
    b.resume_from(&path).unwrap();
    b.run(3).unwrap();
    std::fs::remove_file(&path).ok();
    // the resumed run announces itself on the trace and the gauge
    assert!(b.export_trace().contains("run.resumed"));
    let mut seen = std::collections::BTreeSet::new();
    let mut resume_gauge = None;
    for line in b.export_metrics().lines() {
        let v = Json::parse(line).unwrap();
        let p = v.get("period").and_then(Json::as_usize).unwrap();
        assert!(seen.insert(p), "duplicated metrics snapshot for period {p}");
        if resume_gauge.is_none() {
            resume_gauge = v
                .get("gauges")
                .and_then(|g| g.get("ckpt.resume_period"))
                .and_then(Json::as_f64);
        }
    }
    assert_eq!(resume_gauge, Some(3.0));
    // snapshots cover only the post-resume periods, each exactly once
    assert_eq!(seen.into_iter().collect::<Vec<_>>(), vec![4, 5, 6]);
    // the audit ledger restarts at the resumed period too
    let audit = b.export_audit();
    let first = Json::parse(audit.lines().next().unwrap()).unwrap();
    assert_eq!(first.get("period").and_then(Json::as_usize), Some(4));
    assert_eq!(audit.lines().count(), 3);
}

#[test]
fn faulted_hier_trace_carries_outage_and_merge_events() {
    let run = run_hier(0.5, 0, true, 8);
    // outage rate 0.5 over 3 cells x 4 tau-blocks: some block lost a
    // cell (ragged logs), pinned by the counter-derived outage stream
    assert!(run.log.records.len() < 3 * 8, "no outage fired");
    assert!(run.trace.contains("\"cell_outage\""));
    assert!(run.trace.contains("\"cloud_merge\""));
    // the outage counter lives on the cloud lane (the hier sink draws the
    // masks), while the instants land on the affected cells' own lanes
    let cloud = last_cloud_snapshot(&run.metrics, 3);
    let outages = cloud.get("counters").unwrap().get("fault.cell_outages").unwrap().as_usize();
    assert!(outages.unwrap() > 0);
}
