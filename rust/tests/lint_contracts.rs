//! Tier-1 contract pin: `feel lint` must report zero findings on the
//! tree, and every rule must be proven live by a planted violation.
//!
//! The tree walk covers `src/` + `benches/` (tests are exempt — this
//! file plants violations on purpose, via in-memory fixtures only).

use std::path::Path;

use feel::analysis::{check_tags, lint_source, lint_tree, render_text, Rule};

/// Findings for a fixture snippet placed at `rel`.
fn lint(rel: &str, src: &str) -> Vec<Rule> {
    lint_source(rel, src).0.into_iter().map(|f| f.rule).collect()
}

#[test]
fn tree_has_zero_findings() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = lint_tree(root).expect("lint walk failed");
    assert!(
        findings.is_empty(),
        "determinism contract violations — fix them or pragma with a reason:\n{}",
        render_text(&findings)
    );
}

#[test]
fn r1_float_sort_fires() {
    let src = r#"
        pub fn pick(xs: &mut [f64]) {
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        }
    "#;
    assert!(lint("src/grad/fix.rs", src).contains(&Rule::FloatSort));
    // the sanctioned form is clean (and carries no R5 token either)
    let ok = "pub fn pick(xs: &mut [f64]) { xs.sort_by(|a, b| a.total_cmp(b)); }";
    assert!(lint("src/grad/fix.rs", ok).is_empty());
}

#[test]
fn r2_tag_registry_catches_collisions_zero_and_nonliteral() {
    let src = "pub const A_TAG: u64 = 0xdead; pub const B_TAG: u64 = 0xdead;\n\
               pub const Z_TAG: u64 = 0;";
    let (findings, tags) = lint_source("src/fault/fix.rs", src);
    assert!(findings.is_empty(), "collection itself emits nothing");
    assert_eq!(tags.len(), 3);
    let probs = check_tags(&tags);
    assert_eq!(probs.len(), 2, "one collision + one zero: {probs:?}");
    assert!(probs.iter().all(|f| f.rule == Rule::TagRegistry));
    // a tag the registry cannot parse is a finding at collection time
    let (findings, tags) = lint_source("src/fault/fix.rs", "const C_TAG: u64 = derive();");
    assert!(tags.is_empty());
    assert_eq!(findings.iter().filter(|f| f.rule == Rule::TagRegistry).count(), 1);
}

#[test]
fn r3_hash_iter_fires_in_deterministic_modules_only() {
    let src = "use std::collections::HashMap;";
    assert!(lint("src/sched/fix.rs", src).contains(&Rule::HashIter));
    let rules = lint("src/grad/fix.rs", "fn f() -> HashSet<u32> { todo() }");
    assert!(rules.contains(&Rule::HashIter));
    // non-deterministic modules and benches may hash
    assert!(lint("src/wireless/fix.rs", src).is_empty());
    assert!(lint("benches/fix.rs", src).is_empty());
}

#[test]
fn r4_wall_clock_confined_to_allowlist() {
    let src = "fn f() { let t = Instant::now(); }";
    assert!(lint("src/sched/fix.rs", src).contains(&Rule::WallClock));
    let rules = lint("src/hier/fix.rs", "fn f() { let t = SystemTime::now(); }");
    assert!(rules.contains(&Rule::WallClock));
    assert!(lint("src/benchkit.rs", src).is_empty());
    assert!(lint("src/runtime/client.rs", src).is_empty());
    let pragmad = "fn f() {\n\
                   // lint: allow(wall-clock): wall-time accounting only\n\
                   let t = Instant::now();\n}";
    assert!(lint("src/sched/fix.rs", pragmad).is_empty());
}

#[test]
fn r5_panic_path_fires_and_pragmas_suppress() {
    let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
    assert!(lint("src/obs/fix.rs", src).contains(&Rule::PanicPath));
    let rules = lint("src/obs/fix.rs", "fn f(x: Option<u32>) -> u32 { x.expect(\"set\") }");
    assert!(rules.contains(&Rule::PanicPath));
    let pragmad = "fn f(x: Option<u32>) -> u32 {\n\
                   // lint: allow(panic-path): caller always sets x\n\
                   x.unwrap()\n}";
    assert!(lint("src/obs/fix.rs", pragmad).is_empty());
    // a pragma without a written reason suppresses nothing and is itself
    // a finding
    let bare = "fn f(x: Option<u32>) -> u32 {\n\
                // lint: allow(panic-path):\n\
                x.unwrap()\n}";
    let rules = lint("src/obs/fix.rs", bare);
    assert!(rules.contains(&Rule::Pragma), "{rules:?}");
    assert!(rules.contains(&Rule::PanicPath), "{rules:?}");
    // unwrap_or and friends are not panic paths
    let rules = lint("src/obs/fix.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap_or(0) }");
    assert!(rules.is_empty());
}

#[test]
fn r6_rng_sources_outside_util_rng() {
    let rules = lint("src/device/fix.rs", "let mut rng = rand::thread_rng();");
    assert!(rules.contains(&Rule::RngSource));
    assert!(lint("src/grad/fix.rs", "let h = RandomState::new();").contains(&Rule::RngSource));
    assert!(lint("src/device/fix.rs", "let r = Pcg::new(1, 2);").contains(&Rule::RngSource));
    // util::rng itself constructs freely; the sanctioned derivations are
    // clean everywhere
    assert!(lint("src/util/rng.rs", "let r = Pcg::new(1, 2);").is_empty());
    assert!(lint("src/device/fix.rs", "let r = Pcg::for_device(seed, p, k);").is_empty());
    // benches are NOT exempt from R6
    let rules = lint("benches/fix.rs", "let mut rng = rand::thread_rng();");
    assert!(rules.contains(&Rule::RngSource));
}

#[test]
fn literals_and_comments_never_false_positive() {
    let src = r##"
        // unwrap() partial_cmp HashMap Instant::now in a comment
        /* thread_rng /* nested SystemTime */ still a comment */
        fn f() -> &'static str {
            let s = "thread_rng unwrap() HashMap SystemTime";
            let r = r#"Instant::now() . unwrap ( )"#;
            let c = 'u';
            let b = b'x';
            s
        }
    "##;
    assert!(lint("src/sched/fix.rs", src).is_empty());
}

#[test]
fn test_code_is_exempt() {
    let src = "
        #[cfg(test)]
        mod tests {
            use std::collections::HashMap;
            fn helper(x: Option<u32>) -> u32 { x.unwrap() }
        }
        #[test]
        fn t() { y.unwrap(); }
    ";
    assert!(lint("src/grad/fix.rs", src).is_empty());
    // and integration-test files are skipped wholesale
    assert!(lint("tests/fix.rs", "fn f() { x.unwrap(); let t = Instant::now(); }").is_empty());
}

#[test]
fn benches_are_exempt_from_panic_and_clock_rules() {
    let src = "fn main() { let t = Instant::now(); run().unwrap(); }";
    assert!(lint("benches/fix.rs", src).is_empty());
}
