//! Checkpoint/resume is exact or it is nothing: a run interrupted at
//! period p and resumed from its checkpoint must reproduce the
//! uninterrupted run bitwise — under every round policy, with stragglers,
//! client sampling, fault injection, and the quarantine all active, flat
//! and hierarchical. Damaged files (truncated, bit-flipped, re-versioned,
//! wrong topology kind, wrong run configuration) are rejected with
//! structured errors and leave the trainer untouched and usable.

use std::fs;
use std::path::PathBuf;

use feel::coordinator::checkpoint::{self, fnv1a64};
use feel::coordinator::{BackendSet, HostBackend, TrainLog, Trainer, TrainerConfig};
use feel::data::{generate, Partition, SynthConfig};
use feel::device::{paper_cpu_fleet, StragglerModel};
use feel::fault::FaultPlan;
use feel::grad::{GradGuard, Quarantine};
use feel::hier::{CellWorld, HierConfig, HierTrainer};
use feel::sched::RoundPolicy;
use feel::util::rng::Pcg;
use feel::wireless::CellConfig;

fn tmp(label: &str) -> PathBuf {
    std::env::temp_dir().join(format!("feel_ckpt_it_{}_{label}.ckpt", std::process::id()))
}

fn assert_logs_equal(a: &TrainLog, b: &TrainLog, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: period count");
    for (x, y) in a.records.iter().zip(&b.records) {
        let p = x.period;
        assert_eq!(x.period, y.period, "{label} p{p}");
        assert_eq!(x.b_total, y.b_total, "{label} p{p}: b_total");
        assert_eq!(
            x.train_loss.to_bits(),
            y.train_loss.to_bits(),
            "{label} p{p}: train_loss {} vs {}",
            x.train_loss,
            y.train_loss
        );
        assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "{label} p{p}: sim_time");
        assert_eq!(x.t_period.to_bits(), y.t_period.to_bits(), "{label} p{p}: t_period");
        assert_eq!(x.lr.to_bits(), y.lr.to_bits(), "{label} p{p}: lr");
        assert_eq!(
            x.test_loss.map(f64::to_bits),
            y.test_loss.map(f64::to_bits),
            "{label} p{p}: test_loss"
        );
        assert_eq!(x.applied, y.applied, "{label} p{p}: applied");
        assert_eq!(x.dropped, y.dropped, "{label} p{p}: dropped");
        assert_eq!(x.late, y.late, "{label} p{p}: late");
        assert_eq!(
            x.stale_mean.to_bits(),
            y.stale_mean.to_bits(),
            "{label} p{p}: stale_mean"
        );
        assert_eq!(x.cell, y.cell, "{label} p{p}: cell");
        assert_eq!(x.cloud, y.cloud, "{label} p{p}: cloud");
        assert_eq!(x.crashed, y.crashed, "{label} p{p}: crashed");
        assert_eq!(x.corrupt, y.corrupt, "{label} p{p}: corrupt");
        assert_eq!(x.quarantined, y.quarantined, "{label} p{p}: quarantined");
    }
}

/// The headline contract: interrupt at period 4, resume, run 4 more —
/// the log (all 18 columns) is bitwise the uninterrupted 8-period run,
/// under sync, deadline, and async, with and without active faults. The
/// save → resume → save cycle is also byte-identical, so every field the
/// checkpoint carries provably roundtrips.
#[test]
fn resume_reproduces_uninterrupted_flat_run_bitwise_all_policies() {
    let cfg = SynthConfig { dim: 24, ..Default::default() };
    let train = generate(&cfg, 800, 1);
    let test = generate(&cfg, 200, 1);
    let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
    let faults = [
        (FaultPlan::none(), GradGuard::off()),
        (
            FaultPlan::new(0.1, 2, 0.05, 0.0, 0.0).unwrap(),
            GradGuard::new(Quarantine::Reject, f64::INFINITY).unwrap(),
        ),
    ];
    for (i, policy) in [
        RoundPolicy::Sync,
        RoundPolicy::Deadline { factor: 1.25 },
        RoundPolicy::Async { alpha: 0.6, beta: 0.5, quorum: 0.5 },
    ]
    .into_iter()
    .enumerate()
    {
        for (j, (fault, guard)) in faults.into_iter().enumerate() {
            let tc = TrainerConfig {
                policy,
                straggler: StragglerModel::new(0.5, 0.1).unwrap(),
                sample_frac: 0.5,
                fault,
                guard,
                eval_every: 4,
                ..Default::default()
            };
            let mk = || {
                let mut rng = Pcg::seeded(2);
                let fleet =
                    paper_cpu_fleet(4, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
                Trainer::new(tc.clone(), fleet, &train, &test, Partition::Iid, &be).unwrap()
            };
            let label = format!("{policy:?} faults={}", fault.is_active());
            let mut full = mk();
            full.run(8).unwrap();

            let path = tmp(&format!("flat_{i}_{j}"));
            let mut head = mk();
            head.run(4).unwrap();
            head.save_checkpoint(&path).unwrap();
            drop(head);

            let mut tail = mk();
            tail.resume_from(&path).unwrap();
            // a restored trainer re-serializes to the identical file:
            // nothing the checkpoint carries is lost in restore
            let again = tmp(&format!("flat_again_{i}_{j}"));
            tail.save_checkpoint(&again).unwrap();
            assert_eq!(
                fs::read(&path).unwrap(),
                fs::read(&again).unwrap(),
                "{label}: save -> resume -> save drifted"
            );
            tail.run(4).unwrap();
            assert_logs_equal(&full.log, &tail.log, &label);
            assert_eq!(full.log.to_csv(), tail.log.to_csv(), "{label}: csv");
            let _ = fs::remove_file(&path);
            let _ = fs::remove_file(&again);
        }
    }
}

/// Same contract one level up: a 3-cell hierarchy with mixed per-cell
/// policies, stragglers, and cell-outage injection active, interrupted
/// at the 2nd of 4 cloud blocks, resumes to a bitwise-identical merged
/// log, cloud-round count, and simulated clock.
#[test]
fn hier_resume_with_cell_outage_reproduces_uninterrupted_run() {
    let k_cell = 4;
    let cfg = SynthConfig { dim: 12, ..Default::default() };
    let train = generate(&cfg, 3 * 20 * k_cell, 1);
    let test = generate(&cfg, 200, 1);
    let be = HostBackend::for_model("mini_res", 12, 10, 3).unwrap();
    let cell_train: Vec<_> = (0..3)
        .map(|c| train.subset(&(c * 80..(c + 1) * 80).collect::<Vec<_>>()))
        .collect();
    let fault = FaultPlan::new(0.0, 1, 0.0, 0.0, 0.5).unwrap();
    let tc = TrainerConfig {
        straggler: StragglerModel::new(0.5, 0.1).unwrap(),
        fault,
        b_max: 8,
        eval_every: 0,
        ..Default::default()
    };
    let hc = HierConfig {
        tau: 2,
        policies: vec![
            RoundPolicy::Sync,
            RoundPolicy::Deadline { factor: 1.25 },
            RoundPolicy::Async { alpha: 0.6, beta: 0.5, quorum: 0.5 },
        ],
        ..Default::default()
    };
    let mk = || {
        let mut rng = Pcg::seeded(2);
        let cell_cfg = CellConfig::default().split_bandwidth(3);
        let worlds: Vec<CellWorld> = cell_train
            .iter()
            .map(|tr| CellWorld {
                fleet: paper_cpu_fleet(k_cell, 7e7, 1e8, cell_cfg, 4.0, 0.5, &mut rng),
                backends: BackendSet::homogeneous(k_cell, "mini_res", &be),
                train: tr,
            })
            .collect();
        HierTrainer::new(tc.clone(), hc.clone(), worlds, &test, Partition::Iid).unwrap()
    };
    // the outage stream is a pure function of (base seed, block, cell);
    // confirm it actually fires inside the 4 cloud blocks this test runs
    assert!(
        (0..4u64).any(|b| (0..3u64).any(|c| fault.cell_out(tc.seed, b, c))),
        "outage never fires in this window — pick another seed or rate"
    );

    let mut full = mk();
    full.run(8).unwrap();
    let log_full = full.merged_log();
    // an outage fired, so some cell skipped a whole tau-block of records
    assert!(log_full.records.len() < 24, "no cell ever missed a block");
    assert!(!log_full.records.is_empty());

    let path = tmp("hier");
    let mut head = mk();
    head.run(4).unwrap();
    head.save_checkpoint(&path).unwrap();
    drop(head);

    let mut tail = mk();
    tail.resume_from(&path).unwrap();
    tail.run(4).unwrap();
    assert_eq!(full.cloud_rounds(), tail.cloud_rounds());
    assert_eq!(full.sim_time().to_bits(), tail.sim_time().to_bits());
    assert_logs_equal(&log_full, &tail.merged_log(), "hier resume");
    let _ = fs::remove_file(&path);
}

/// Every damage mode is a structured error, never a panic — and a failed
/// restore leaves the trainer exactly as it was: running it afterwards
/// matches a trainer that never saw the bad file, bitwise.
#[test]
fn corrupted_checkpoint_files_rejected_without_partial_state() {
    let cfg = SynthConfig { dim: 24, ..Default::default() };
    let train = generate(&cfg, 800, 1);
    let test = generate(&cfg, 200, 1);
    let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
    let tc = TrainerConfig { eval_every: 0, ..Default::default() };
    let mk = |seed: u64| {
        let mut rng = Pcg::seeded(2);
        let fleet = paper_cpu_fleet(4, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
        let cfg = TrainerConfig { seed, ..tc.clone() };
        Trainer::new(cfg, fleet, &train, &test, Partition::Iid, &be).unwrap()
    };
    let mut src = mk(0);
    src.run(3).unwrap();
    let path = tmp("valid");
    src.save_checkpoint(&path).unwrap();
    let raw = fs::read(&path).unwrap();
    let _ = fs::remove_file(&path);

    let try_resume = |bytes: &[u8], seed: u64, label: &str| -> String {
        let p = tmp(label);
        fs::write(&p, bytes).unwrap();
        let err = mk(seed).resume_from(&p).unwrap_err();
        let _ = fs::remove_file(&p);
        format!("{err:#}")
    };

    // frame-level truncation: shorter than any valid checkpoint
    let err = try_resume(&raw[..10], 0, "trunc_frame");
    assert!(err.contains("truncated"), "{err}");
    // payload truncation: frame intact but bytes missing
    let err = try_resume(&raw[..raw.len() - 20], 0, "trunc_payload");
    assert!(err.contains("truncated or padded"), "{err}");
    // not our file at all
    let mut bad = raw.clone();
    bad[0] ^= 0xff;
    let err = try_resume(&bad, 0, "magic");
    assert!(err.contains("bad magic"), "{err}");
    // a future layout version is refused, not misparsed
    let mut bad = raw.clone();
    bad[8] = 0xff;
    let err = try_resume(&bad, 0, "version");
    assert!(err.contains("layout version"), "{err}");
    // wrong topology kind (checksum repaired so the kind check is what fires)
    let mut bad = raw.clone();
    bad[12] = checkpoint::KIND_HIER;
    let n = bad.len();
    let sum = fnv1a64(&bad[..n - 8]);
    bad[n - 8..].copy_from_slice(&sum.to_le_bytes());
    let err = try_resume(&bad, 0, "kind");
    assert!(err.contains("hierarchical run, expected flat"), "{err}");
    // a single flipped payload bit fails the checksum
    let mut bad = raw.clone();
    let mid = raw.len() / 2;
    bad[mid] ^= 0x01;
    let err = try_resume(&bad, 0, "bitflip");
    assert!(err.contains("checksum"), "{err}");
    // a checkpoint from a differently-configured run is refused up front
    let err = try_resume(&raw, 3, "digest");
    assert!(err.contains("different run configuration"), "{err}");

    // a well-framed file whose payload ends mid-field fails the parse —
    // and the trainer it failed into is untouched: it runs on to the
    // same numbers as a twin that never saw the file
    const HEADER: usize = 8 + 4 + 1 + 8;
    let payload = &raw[HEADER..raw.len() - 8];
    let p = tmp("short_payload");
    checkpoint::write_file(&p, checkpoint::KIND_FLAT, &payload[..payload.len() - 3])
        .unwrap();
    let mut damaged = mk(0);
    assert!(damaged.resume_from(&p).is_err());
    let _ = fs::remove_file(&p);
    damaged.run(3).unwrap();
    let mut clean = mk(0);
    clean.run(3).unwrap();
    assert_logs_equal(&clean.log, &damaged.log, "post-failed-resume");
}
