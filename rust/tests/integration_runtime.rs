//! Integration: AOT XLA path vs pure-rust host model on identical inputs.
//!
//! This is the cross-layer correctness signal: the jax/Pallas train_step
//! (lowered to HLO, executed by PJRT) and the independently-written rust
//! oracle must agree on loss, accuracy and every gradient component.
//!
//! Requires `make artifacts`; tests self-skip (with a notice) if the
//! directory is missing so `cargo test` works in a fresh checkout.

use std::path::PathBuf;

use feel::runtime::hostmodel::HostModel;
use feel::runtime::{Kind, Runtime};
use feel::util::rng::Pcg;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var("FEEL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let p = PathBuf::from(dir);
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: no artifacts at {} (run `make artifacts`)", p.display());
        None
    }
}

fn batch(n: usize, d: usize, c: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut r = Pcg::seeded(seed);
    let x: Vec<f32> = (0..n * d).map(|_| r.normal() as f32).collect();
    let y: Vec<i32> = (0..n).map(|_| r.below(c as u64) as i32).collect();
    (x, y)
}

#[test]
fn xla_matches_host_model_all_models() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).expect("load runtime");
    let models: Vec<String> = rt.manifest.models.keys().cloned().collect();
    let d = rt.manifest.input_dim;
    let c = rt.manifest.classes;
    for model in models {
        let meta = rt.manifest.model(&model).unwrap().clone();
        let host = HostModel::from_layout(&model, &meta.layout, d, c).unwrap();
        let params = rt.init_params(&model).unwrap();
        assert_eq!(params.len(), meta.params);

        let bucket = *rt.manifest.buckets.first().unwrap().max(&1);
        let (x, y) = batch(bucket, d, c, 42);
        let w = vec![1f32; bucket];

        let xla = rt.train_step(&model, &params, &x, &y, &w, bucket).unwrap();
        let (hg, hl, hc) = host.train_step(&params, &x, &y, &w);

        assert!(
            (xla.loss - hl).abs() < 1e-4 * (1.0 + hl.abs()),
            "{model}: loss xla={} host={hl}",
            xla.loss
        );
        assert_eq!(xla.correct, hc, "{model}: correct");
        assert_eq!(xla.grads.len(), hg.len());
        let mut max_abs = 0f32;
        let mut max_err = 0f32;
        for (a, b) in xla.grads.iter().zip(&hg) {
            max_abs = max_abs.max(b.abs());
            max_err = max_err.max((a - b).abs());
        }
        assert!(
            max_err < 1e-4 + 1e-3 * max_abs,
            "{model}: grad max err {max_err} (max |g| {max_abs})"
        );
        println!("{model}: grads agree (max err {max_err:.2e})");
    }
}

#[test]
fn padded_bucket_semantics_exact() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).expect("load runtime");
    let model = rt.manifest.models.keys().next().unwrap().clone();
    let d = rt.manifest.input_dim;
    let c = rt.manifest.classes;
    let params = rt.init_params(&model).unwrap();

    // A true batch of n rows, padded into a larger bucket, must equal the
    // host model on exactly those n rows.
    let buckets = rt.manifest.buckets.clone();
    let Some(&big) = buckets.iter().find(|&&b| b >= 3) else { return };
    let n = big - 1; // deliberately not a bucket size when big > 2
    let (x, y) = batch(n.max(1), d, c, 7);
    let out = rt.train_step_padded(&model, &params, &x, &y).unwrap();

    let meta = rt.manifest.model(&model).unwrap().clone();
    let host = HostModel::from_layout(&model, &meta.layout, d, c).unwrap();
    let w = vec![1f32; n.max(1)];
    let (hg, hl, _) = host.train_step(&params, &x, &y, &w);
    assert!((out.loss - hl).abs() < 1e-4 * (1.0 + hl.abs()), "loss {} vs {hl}", out.loss);
    let max_err = out
        .grads
        .iter()
        .zip(&hg)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 2e-3, "padded grads differ: {max_err}");
}

#[test]
fn apply_update_is_sgd() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).expect("load runtime");
    let model = rt.manifest.models.keys().next().unwrap().clone();
    let params = rt.init_params(&model).unwrap();
    let grads: Vec<f32> = params.iter().map(|p| p * 0.5 + 0.01).collect();
    let lr = 0.1f32;
    let out = rt.apply_update(&model, &params, &grads, lr).unwrap();
    for i in 0..params.len() {
        let want = params[i] - lr * grads[i];
        assert!((out[i] - want).abs() < 1e-6, "param {i}: {} vs {want}", out[i]);
    }
}

#[test]
fn evaluate_matches_host_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).expect("load runtime");
    let model = rt.manifest.models.keys().next().unwrap().clone();
    let d = rt.manifest.input_dim;
    let c = rt.manifest.classes;
    let eb = rt.manifest.eval_batch;
    let params = rt.init_params(&model).unwrap();
    let (x, y) = batch(eb, d, c, 9);
    let out = rt.evaluate(&model, &params, &x, &y).unwrap();

    let meta = rt.manifest.model(&model).unwrap().clone();
    let host = HostModel::from_layout(&model, &meta.layout, d, c).unwrap();
    let w = vec![1f32; eb];
    let (hl, hc) = host.loss(&params, &x, &y, &w);
    assert!((out.loss - hl).abs() < 1e-4 * (1.0 + hl.abs()));
    assert_eq!(out.correct, hc);
    assert!((0.0..=eb as f32).contains(&out.correct));
}

#[test]
fn training_reduces_loss_via_xla() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).expect("load runtime");
    let model = rt.manifest.models.keys().next().unwrap().clone();
    let d = rt.manifest.input_dim;
    let c = rt.manifest.classes;
    let mut params = rt.init_params(&model).unwrap();
    let bucket = rt.manifest.max_bucket().min(16);
    let (x, y) = batch(bucket, d, c, 21);
    let w = vec![1f32; bucket];

    let first = rt.train_step(&model, &params, &x, &y, &w, bucket).unwrap();
    let mut loss = first.loss;
    params = rt.apply_update(&model, &params, &first.grads, 0.1).unwrap();
    for _ in 0..20 {
        let s = rt.train_step(&model, &params, &x, &y, &w, bucket).unwrap();
        loss = s.loss;
        params = rt.apply_update(&model, &params, &s.grads, 0.1).unwrap();
    }
    assert!(
        loss < first.loss * 0.7,
        "XLA training did not reduce loss: {} -> {loss}",
        first.loss
    );
}

#[test]
fn manifest_kinds_complete() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("load runtime");
    for model in rt.manifest.models.keys() {
        for &b in &rt.manifest.buckets {
            assert!(rt.manifest.find(model, Kind::TrainStep, b).is_some());
        }
        assert!(rt.manifest.find(model, Kind::ApplyUpdate, 0).is_some());
        assert!(rt.manifest.find(model, Kind::Init, 0).is_some());
    }
}
