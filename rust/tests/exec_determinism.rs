//! The exec engine's core invariant: identical numerics at any thread
//! count. Running the same configuration with 1, 2, and 8 worker threads
//! must produce bitwise-identical `TrainLog` records — batch sampling uses
//! counter-derived per-(seed, period, device) RNG streams and every
//! cross-device reduction happens in fixed device order, so thread
//! scheduling can never leak into results. The sharded gradient reduce
//! keeps the invariant because shard boundaries are a pure function of the
//! fleet size K (see `exec::agg_shard_size`), never of the thread count.

use feel::coordinator::{
    Backend, BackendSet, HostBackend, Scheme, TrainLog, Trainer, TrainerConfig,
};
use feel::data::{generate, DeviceData, Partition, SynthConfig};
use feel::device::{paper_cpu_fleet, StragglerModel};
use feel::exec::{agg_shard_size, gradient_round_sharded, Engine};
use feel::grad::Aggregator;
use feel::hier::{CellWorld, HierConfig, HierTrainer};
use feel::sched::RoundPolicy;
use feel::util::rng::Pcg;
use feel::wireless::CellConfig;

fn run_with_threads(scheme: Scheme, threads: usize, periods: usize) -> TrainLog {
    let cfg = SynthConfig { dim: 24, ..Default::default() };
    let train = generate(&cfg, 800, 1);
    let test = generate(&cfg, 200, 1);
    let mut rng = Pcg::seeded(2);
    let fleet = paper_cpu_fleet(4, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
    let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
    let tc = TrainerConfig { scheme, threads, eval_every: 4, ..Default::default() };
    let mut tr = Trainer::new(tc, fleet, &train, &test, Partition::Iid, &be).unwrap();
    tr.run(periods).unwrap();
    tr.log.clone()
}

fn assert_bitwise_equal(a: &TrainLog, b: &TrainLog, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: period count");
    for (x, y) in a.records.iter().zip(&b.records) {
        let p = x.period;
        assert_eq!(x.period, y.period, "{label} p{p}");
        assert_eq!(x.b_total, y.b_total, "{label} p{p}: b_total");
        assert_eq!(
            x.train_loss.to_bits(),
            y.train_loss.to_bits(),
            "{label} p{p}: train_loss {} vs {}",
            x.train_loss,
            y.train_loss
        );
        assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "{label} p{p}: sim_time");
        assert_eq!(x.t_period.to_bits(), y.t_period.to_bits(), "{label} p{p}: t_period");
        assert_eq!(x.lr.to_bits(), y.lr.to_bits(), "{label} p{p}: lr");
        assert_eq!(
            x.efficiency.to_bits(),
            y.efficiency.to_bits(),
            "{label} p{p}: efficiency"
        );
        assert_eq!(
            x.test_loss.map(f64::to_bits),
            y.test_loss.map(f64::to_bits),
            "{label} p{p}: test_loss"
        );
        assert_eq!(
            x.test_acc.map(f64::to_bits),
            y.test_acc.map(f64::to_bits),
            "{label} p{p}: test_acc"
        );
    }
}

#[test]
fn proposed_identical_at_1_2_8_threads() {
    let base = run_with_threads(Scheme::Proposed, 1, 10);
    for t in [2usize, 8] {
        let par = run_with_threads(Scheme::Proposed, t, 10);
        assert_bitwise_equal(&base, &par, &format!("proposed t={t}"));
    }
    // and the run actually learns, so the equality is not vacuous
    assert!(base.records[9].train_loss < base.records[0].train_loss);
}

#[test]
fn gradient_fl_identical_across_threads() {
    let base = run_with_threads(Scheme::GradientFl, 1, 4);
    let par = run_with_threads(Scheme::GradientFl, 8, 4);
    assert_bitwise_equal(&base, &par, "gradient_fl");
}

#[test]
fn model_fl_identical_across_threads() {
    let base = run_with_threads(Scheme::ModelFl { local_batch: 32 }, 1, 4);
    let par = run_with_threads(Scheme::ModelFl { local_batch: 32 }, 8, 4);
    assert_bitwise_equal(&base, &par, "model_fl");
}

#[test]
fn individual_identical_across_threads() {
    // exercises the per-device eval fan-out too (eval_every fires)
    let base = run_with_threads(Scheme::Individual { local_batch: 64 }, 1, 6);
    let par = run_with_threads(Scheme::Individual { local_batch: 64 }, 8, 6);
    assert_bitwise_equal(&base, &par, "individual");
}

/// The sharded gradient round (engine workers folding contiguous device
/// ranges into local aggregator shards) must produce a bitwise-identical
/// global gradient and loss at any thread count — including at K > 32,
/// where shards span multiple devices and worker chunks don't align with
/// single devices.
#[test]
fn sharded_gradient_round_thread_invariant() {
    use feel::coordinator::worker::Worker;

    let k = 40; // -> agg_shard_size = 2: multi-device shards
    assert_eq!(agg_shard_size(k), 2);
    let cfg = SynthConfig { dim: 12, ..Default::default() };
    let train = generate(&cfg, 20 * k, 1);
    let be = HostBackend::for_model("mini_dense", 12, 10, 2).unwrap();
    let set = BackendSet::homogeneous(k, "mini_dense", &be);
    let fams = vec![be.init_params().unwrap()];
    let batches = vec![4usize; k];

    let run = |threads: usize| {
        let mut workers: Vec<Worker> = (0..k)
            .map(|id| {
                let idx: Vec<usize> = (id * 20..(id + 1) * 20).collect();
                Worker::new(id, DeviceData::new(idx, Pcg::seeded(id as u64)), None)
            })
            .collect();
        let shards = gradient_round_sharded(
            &Engine::new(threads),
            &set,
            &mut workers,
            &fams,
            &train,
            &batches,
            11,
            5,
        )
        .unwrap();
        assert_eq!(shards.len(), k.div_ceil(agg_shard_size(k)));
        let mut loss = 0f64;
        let mut weight = 0f64;
        for s in &shards {
            loss += s.loss;
            weight += s.weight;
        }
        let global = Aggregator::reduce_shards(
            shards.into_iter().flat_map(|s| s.aggs.into_iter().map(|(_, a)| a)).collect(),
        )
        .unwrap()
        .finish()
        .unwrap();
        (global, loss.to_bits(), weight.to_bits())
    };

    let base = run(1);
    for t in [2usize, 8] {
        let par = run(t);
        assert_eq!(base.0, par.0, "t={t}: global gradient");
        assert_eq!(base.1, par.1, "t={t}: loss bits");
        assert_eq!(base.2, par.2, "t={t}: weight bits");
    }
}

/// Aggregator shard-merge property: for integer-valued contributions
/// (exact in f64), merging per-shard aggregators in device order equals the
/// streaming device-order `add` path bitwise; for arbitrary floats the two
/// groupings agree to f64 rounding.
#[test]
fn aggregator_shard_merge_property() {
    let mut rng = Pcg::seeded(42);
    for trial in 0..20u64 {
        let p = 64;
        let k = 2 + (trial % 7) as usize;
        let shard_size = 1 + (trial % 3) as usize;
        // integer-valued case: exact equality
        let grads: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..p).map(|_| (rng.below(41) as f32) - 20.0).collect())
            .collect();
        let weights: Vec<f64> = (0..k).map(|_| (1 + rng.below(64)) as f64).collect();

        let mut stream = Aggregator::new(p);
        for (g, &w) in grads.iter().zip(&weights) {
            stream.add(g, w).unwrap();
        }
        let shards: Vec<Aggregator> = grads
            .chunks(shard_size)
            .zip(weights.chunks(shard_size))
            .map(|(gs, ws)| {
                let mut a = Aggregator::new(p);
                for (g, &w) in gs.iter().zip(ws) {
                    a.add(g, w).unwrap();
                }
                a
            })
            .collect();
        let merged = Aggregator::reduce_shards(shards).unwrap();
        assert_eq!(merged.contributions(), stream.contributions(), "trial {trial}");
        assert_eq!(
            merged.finish().unwrap(),
            stream.finish().unwrap(),
            "trial {trial}: integer shard-merge must be exact"
        );

        // float case: agreement to f64 rounding
        let grads: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..p).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut stream = Aggregator::new(p);
        for (g, &w) in grads.iter().zip(&weights) {
            stream.add(g, w).unwrap();
        }
        let shards: Vec<Aggregator> = grads
            .chunks(shard_size)
            .zip(weights.chunks(shard_size))
            .map(|(gs, ws)| {
                let mut a = Aggregator::new(p);
                for (g, &w) in gs.iter().zip(ws) {
                    a.add(g, w).unwrap();
                }
                a
            })
            .collect();
        let merged = Aggregator::reduce_shards(shards).unwrap().finish().unwrap();
        let streamed = stream.finish().unwrap();
        for (a, b) in merged.iter().zip(&streamed) {
            assert!(
                (a - b).abs() <= 1e-6 * (1.0 + a.abs()),
                "trial {trial}: {a} vs {b}"
            );
        }
    }
}

/// The same invariant for the `sched/` round policies: straggler draws are
/// counter-derived, event ordering is a total order on (time, device), and
/// gradient execution stays on the device-ordered exec rounds — so sync
/// under jitter, deadline, and async runs are all bitwise thread-invariant,
/// including the new participation/staleness columns.
fn run_policy_with_threads(
    policy: RoundPolicy,
    straggler: StragglerModel,
    threads: usize,
    periods: usize,
) -> TrainLog {
    let cfg = SynthConfig { dim: 24, ..Default::default() };
    let train = generate(&cfg, 800, 1);
    let test = generate(&cfg, 200, 1);
    let mut rng = Pcg::seeded(2);
    let fleet = paper_cpu_fleet(4, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
    let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
    let tc = TrainerConfig { policy, straggler, threads, eval_every: 4, ..Default::default() };
    let mut tr = Trainer::new(tc, fleet, &train, &test, Partition::Iid, &be).unwrap();
    tr.run(periods).unwrap();
    tr.log.clone()
}

fn assert_policy_bitwise_equal(a: &TrainLog, b: &TrainLog, label: &str) {
    assert_bitwise_equal(a, b, label);
    for (x, y) in a.records.iter().zip(&b.records) {
        let p = x.period;
        assert_eq!(x.applied, y.applied, "{label} p{p}: applied");
        assert_eq!(x.dropped, y.dropped, "{label} p{p}: dropped");
        assert_eq!(x.late, y.late, "{label} p{p}: late");
        assert_eq!(
            x.stale_mean.to_bits(),
            y.stale_mean.to_bits(),
            "{label} p{p}: stale_mean"
        );
    }
}

#[test]
fn sync_with_straggler_identical_at_1_2_8_threads() {
    let sm = StragglerModel::new(0.5, 0.1).unwrap();
    let base = run_policy_with_threads(RoundPolicy::Sync, sm, 1, 8);
    for t in [2usize, 8] {
        let par = run_policy_with_threads(RoundPolicy::Sync, sm, t, 8);
        assert_policy_bitwise_equal(&base, &par, &format!("sync+straggler t={t}"));
    }
    // the straggler actually fired, so the equality is not vacuous
    assert!(base.records.iter().any(|r| r.dropped > 0));
}

#[test]
fn deadline_identical_at_1_2_8_threads() {
    let sm = StragglerModel::new(0.5, 0.1).unwrap();
    let policy = RoundPolicy::Deadline { factor: 1.25 };
    let base = run_policy_with_threads(policy, sm, 1, 8);
    for t in [2usize, 8] {
        let par = run_policy_with_threads(policy, sm, t, 8);
        assert_policy_bitwise_equal(&base, &par, &format!("deadline t={t}"));
    }
    // both failure paths exercised: dropouts and deadline misses
    assert!(base.records.iter().any(|r| r.dropped > 0));
    assert!(base.records.iter().any(|r| r.late > 0));
}

#[test]
fn async_identical_at_1_2_8_threads() {
    let sm = StragglerModel::new(0.5, 0.1).unwrap();
    let policy = RoundPolicy::Async { alpha: 0.6, beta: 0.5, quorum: 0.5 };
    let base = run_policy_with_threads(policy, sm, 1, 8);
    for t in [2usize, 8] {
        let par = run_policy_with_threads(policy, sm, t, 8);
        assert_policy_bitwise_equal(&base, &par, &format!("async t={t}"));
    }
    // stale gradients were applied, so the staleness path is covered
    assert!(base.records.iter().any(|r| r.stale_mean > 0.0));
}

/// Heterogeneous-fleet form of the invariant: a K = 40 fleet split across
/// two host model families (multi-device shards that mix families inside
/// one chunk) must stay bitwise thread-invariant under all three round
/// policies. The per-device backend resolution and the per-family shard
/// split are pure functions of the device id, so nothing about thread
/// scheduling can leak in.
fn run_mixed_with_threads(
    policy: RoundPolicy,
    straggler: StragglerModel,
    threads: usize,
    periods: usize,
) -> TrainLog {
    let k = 40;
    let cfg = SynthConfig { dim: 12, ..Default::default() };
    let train = generate(&cfg, 20 * k, 1);
    let test = generate(&cfg, 200, 1);
    let mut rng = Pcg::seeded(2);
    let fleet = paper_cpu_fleet(k, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
    let dense = HostBackend::for_model("mini_dense", 12, 10, 3).unwrap();
    let res = HostBackend::for_model("mini_res", 12, 10, 3).unwrap();
    // tier-0 devices (id % 3 == 0) run mini_dense, tiers 1/2 run
    // mini_res — the worked two-tier example from the README
    let set = BackendSet::new(
        vec![
            ("mini_dense".into(), &dense as &dyn Backend),
            ("mini_res".into(), &res as &dyn Backend),
        ],
        (0..k).map(|id| usize::from(id % 3 != 0)).collect(),
    )
    .unwrap();
    let tc = TrainerConfig {
        policy,
        straggler,
        threads,
        b_max: 8,
        eval_every: 0,
        ..Default::default()
    };
    let mut tr = Trainer::with_backends(tc, fleet, &train, &test, Partition::Iid, set).unwrap();
    tr.run(periods).unwrap();
    tr.log.clone()
}

#[test]
fn mixed_fleet_k40_identical_at_1_2_8_threads_all_policies() {
    let sm = StragglerModel::new(0.5, 0.1).unwrap();
    for policy in [
        RoundPolicy::Sync,
        RoundPolicy::Deadline { factor: 1.25 },
        RoundPolicy::Async { alpha: 0.6, beta: 0.5, quorum: 0.5 },
    ] {
        let base = run_mixed_with_threads(policy, sm, 1, 4);
        for t in [2usize, 8] {
            let par = run_mixed_with_threads(policy, sm, t, 4);
            assert_policy_bitwise_equal(&base, &par, &format!("mixed {policy:?} t={t}"));
        }
        // the straggler fired, so partial-participation folds (empty and
        // mixed-family shards) are actually exercised
        assert!(
            base.records.iter().any(|r| r.dropped > 0),
            "{policy:?}: no dropouts"
        );
        assert!(base.records.iter().all(|r| r.t_period > 0.0));
    }
}

/// The hierarchical degenerate case: one cell at cloud cadence tau = 1
/// must reproduce the flat `Trainer` bitwise — same records, no cell ids,
/// no cloud markers. The whole hier/ compatibility story rests on this:
/// cell 0 keeps the base seed, the single cell owns the whole band and
/// the dataset in natural order, and a single-member cloud merge is a
/// no-op (FedAvg of one model is that model).
#[test]
fn hier_single_cell_tau1_reproduces_flat_trainer_bitwise() {
    let cfg = SynthConfig { dim: 24, ..Default::default() };
    let train = generate(&cfg, 800, 1);
    let test = generate(&cfg, 200, 1);
    let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
    for (policy, straggler) in [
        (RoundPolicy::Sync, StragglerModel::none()),
        (
            RoundPolicy::Deadline { factor: 1.25 },
            StragglerModel::new(0.5, 0.1).unwrap(),
        ),
    ] {
        let tc = TrainerConfig { policy, straggler, eval_every: 4, ..Default::default() };
        let mut rng = Pcg::seeded(2);
        let fleet = paper_cpu_fleet(4, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
        let mut flat = Trainer::new(tc.clone(), fleet.clone(), &train, &test, Partition::Iid, &be)
            .unwrap();
        flat.run(8).unwrap();
        let world = CellWorld {
            fleet,
            backends: BackendSet::homogeneous(4, "mini_res", &be),
            train: &train,
        };
        let mut hier = HierTrainer::new(
            tc,
            HierConfig { tau: 1, ..Default::default() },
            vec![world],
            &test,
            Partition::Iid,
        )
        .unwrap();
        hier.run(8).unwrap();
        assert_eq!(hier.cloud_rounds(), 8);
        let log = hier.merged_log();
        assert_policy_bitwise_equal(&flat.log, &log, &format!("hier degenerate {policy:?}"));
        for r in &log.records {
            assert_eq!(r.cell, 0);
            assert!(!r.cloud, "a one-cell topology must not mark cloud merges");
        }
    }
}

/// The hierarchical form of the thread-invariance contract: K = 120 over
/// three cells running *different* round policies (sync / deadline /
/// async) with stragglers active, cloud-merged every tau = 2 rounds, must
/// produce a bitwise-identical merged log at 1/2/8 threads. Cells are
/// independent between cloud rounds and every cross-cell reduction runs
/// in fixed cell order on the coordinator thread, so neither the outer
/// (cell) nor the inner (device) fan-out can leak scheduling into
/// results.
fn run_hier_k120(threads: usize) -> TrainLog {
    let k_cell = 40;
    let cfg = SynthConfig { dim: 12, ..Default::default() };
    let train = generate(&cfg, 20 * 3 * k_cell, 1);
    let test = generate(&cfg, 200, 1);
    let be = HostBackend::for_model("mini_res", 12, 10, 3).unwrap();
    // contiguous 800-sample shard per cell
    let cell_train: Vec<_> = (0..3)
        .map(|c| train.subset(&(c * 800..(c + 1) * 800).collect::<Vec<_>>()))
        .collect();
    let mut rng = Pcg::seeded(2);
    let cell_cfg = CellConfig::default().split_bandwidth(3);
    let worlds: Vec<CellWorld> = cell_train
        .iter()
        .map(|tr| CellWorld {
            fleet: paper_cpu_fleet(k_cell, 7e7, 1e8, cell_cfg, 4.0, 0.5, &mut rng),
            backends: BackendSet::homogeneous(k_cell, "mini_res", &be),
            train: tr,
        })
        .collect();
    let tc = TrainerConfig {
        threads,
        b_max: 8,
        eval_every: 0,
        straggler: StragglerModel::new(0.5, 0.1).unwrap(),
        ..Default::default()
    };
    let hc = HierConfig {
        tau: 2,
        policies: vec![
            RoundPolicy::Sync,
            RoundPolicy::Deadline { factor: 1.25 },
            RoundPolicy::Async { alpha: 0.6, beta: 0.5, quorum: 0.5 },
        ],
        ..Default::default()
    };
    let mut hier = HierTrainer::new(tc, hc, worlds, &test, Partition::Iid).unwrap();
    hier.run(4).unwrap();
    hier.merged_log()
}

#[test]
fn hier_k120_c3_mixed_policies_identical_at_1_2_8_threads() {
    let base = run_hier_k120(1);
    for t in [2usize, 8] {
        let par = run_hier_k120(t);
        assert_policy_bitwise_equal(&base, &par, &format!("hier k120 t={t}"));
        // the hierarchy columns are part of the contract too
        for (x, y) in base.records.iter().zip(&par.records) {
            assert_eq!(x.cell, y.cell, "p{} cell", x.period);
            assert_eq!(x.cloud, y.cloud, "p{} cloud", x.period);
        }
    }
    // sanity: 3 cells x 4 periods interleaved period-major, the straggler
    // fired, and the tau = 2 cadence marked periods 2 and 4 in every cell
    assert_eq!(base.records.len(), 12);
    for (i, r) in base.records.iter().enumerate() {
        assert_eq!(r.cell, i % 3, "record {i}");
        assert_eq!(r.period, i / 3 + 1, "record {i}");
    }
    assert!(base.records.iter().any(|r| r.dropped > 0));
    let marked: Vec<usize> =
        base.records.iter().filter(|r| r.cloud).map(|r| r.period).collect();
    assert_eq!(marked, vec![2, 2, 2, 4, 4, 4]);
}

/// Full participation through the sampling-aware code path must be the
/// legacy trainer, bitwise, under every round policy: `sample_frac = 1.0`
/// disables the sampler (no `Option` detour changes a single float), so
/// the refactor that threaded participant sets through the planner,
/// scheduler, and aggregator is pinned as a pure extension.
fn run_policy_with_frac(
    policy: RoundPolicy,
    straggler: StragglerModel,
    sample_frac: f64,
    threads: usize,
    periods: usize,
) -> TrainLog {
    let cfg = SynthConfig { dim: 24, ..Default::default() };
    let train = generate(&cfg, 800, 1);
    let test = generate(&cfg, 200, 1);
    let mut rng = Pcg::seeded(2);
    let fleet = paper_cpu_fleet(4, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
    let be = HostBackend::for_model("mini_res", 24, 10, 3).unwrap();
    let tc = TrainerConfig {
        policy,
        straggler,
        sample_frac,
        threads,
        eval_every: 4,
        ..Default::default()
    };
    let mut tr = Trainer::new(tc, fleet, &train, &test, Partition::Iid, &be).unwrap();
    tr.run(periods).unwrap();
    tr.log.clone()
}

#[test]
fn sample_frac_one_reproduces_unsampled_trainer_bitwise_all_policies() {
    let sm = StragglerModel::new(0.5, 0.1).unwrap();
    for policy in [
        RoundPolicy::Sync,
        RoundPolicy::Deadline { factor: 1.25 },
        RoundPolicy::Async { alpha: 0.6, beta: 0.5, quorum: 0.5 },
    ] {
        let legacy = run_policy_with_threads(policy, sm, 1, 8);
        let sampled = run_policy_with_frac(policy, sm, 1.0, 1, 8);
        assert_policy_bitwise_equal(&legacy, &sampled, &format!("frac=1.0 {policy:?}"));
    }
}

/// Sampled rounds keep the thread-invariance contract: at K = 200 with a
/// quarter of the fleet participating per round, the participant draw is
/// counter-derived (a pure function of seed and period), the sampled
/// sub-problem is planned in fixed id order, and the scheduler masks
/// non-participants deterministically — so 1/2/8 threads agree bitwise.
#[test]
fn sampled_k200_identical_at_1_2_8_threads() {
    let k = 200;
    let run = |threads: usize| -> TrainLog {
        let cfg = SynthConfig { dim: 12, ..Default::default() };
        let train = generate(&cfg, 8 * k, 1);
        let test = generate(&cfg, 200, 1);
        let mut rng = Pcg::seeded(2);
        let fleet = paper_cpu_fleet(k, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
        let be = HostBackend::for_model("mini_dense", 12, 10, 3).unwrap();
        let tc = TrainerConfig {
            sample_frac: 0.25,
            straggler: StragglerModel::new(0.5, 0.1).unwrap(),
            threads,
            b_max: 8,
            eval_every: 0,
            ..Default::default()
        };
        let mut tr = Trainer::new(tc, fleet, &train, &test, Partition::Iid, &be).unwrap();
        tr.run(6).unwrap();
        tr.log.clone()
    };
    let base = run(1);
    for t in [2usize, 8] {
        let par = run(t);
        assert_policy_bitwise_equal(&base, &par, &format!("sampled k200 t={t}"));
    }
    // roughly a quarter of the fleet closed each round — never all of it —
    // so the equality covers the genuinely sampled path
    for r in &base.records {
        assert!(r.applied < k, "p{}: {} applied", r.period, r.applied);
        assert!(r.applied > 0, "p{}: empty round", r.period);
    }
    assert!(base.records[5].train_loss < base.records[0].train_loss);
}

/// Seeded-jitter regression: the straggler draws are a pure function of
/// (seed, period, device), so WHICH devices straggle at K = 40 is pinned —
/// any change to the PCG streams, the stream tag, or the draw order inside
/// `StragglerModel::sample` shows up here. (Expected values computed from
/// an independent reimplementation of the PCG-XSH-RR / SplitMix64 chain.)
#[test]
fn seeded_jitter_regression_k40() {
    let sm = StragglerModel::new(0.5, 0.2).unwrap();
    let (seed, period) = (11u64, 5u64);
    let perts: Vec<_> = (0..40u64).map(|d| sm.sample(seed, period, d)).collect();
    let dropped: Vec<u64> = (0..40u64).filter(|&d| perts[d as usize].dropped).collect();
    assert_eq!(dropped, vec![10, 14, 16, 24]);
    let heavy: Vec<u64> = (0..40u64).filter(|&d| perts[d as usize].slowdown > 2.0).collect();
    assert_eq!(heavy, vec![17, 20, 27, 28, 37]);
    // the worst straggler and its exact slowdown (libm tolerance)
    let worst = (0..40usize).max_by(|&a, &b| perts[a].slowdown.total_cmp(&perts[b].slowdown));
    assert_eq!(worst, Some(37));
    assert!((perts[37].slowdown - 3.164_510_746_125_846_4).abs() < 1e-9);
    assert!((perts[0].slowdown - 1.209_224_854_261_271_1).abs() < 1e-9);
    // draws replay bit-identically
    for d in 0..40u64 {
        assert_eq!(perts[d as usize], sm.sample(seed, period, d));
    }
}
