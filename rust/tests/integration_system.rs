//! Cross-module integration + property tests over the whole L3 stack
//! (no artifacts needed — host backend).

use feel::config::{Config, Experiment};
use feel::coordinator::{HostBackend, Scheme, Trainer, TrainerConfig};
use feel::data::{generate, partition, Partition, SynthConfig};
use feel::device::paper_cpu_fleet;
use feel::opt::types::{DeviceInst, Instance};
use feel::opt::{solve, solve_downlink, solve_uplink};
use feel::testkit::{forall, F64Range, Gen, PairOf, UsizeRange, VecOf};
use feel::util::rng::Pcg;
use feel::wireless::{CellConfig, PeriodRates};

/// Random-but-valid optimizer instances for property tests.
struct InstGen {
    k: usize,
}

impl Gen for InstGen {
    type Value = (u64, usize);
    fn generate(&self, rng: &mut Pcg) -> (u64, usize) {
        (rng.next_u64(), self.k)
    }
    fn shrink(&self, _v: &(u64, usize)) -> Vec<(u64, usize)> {
        Vec::new()
    }
}

fn instance_from(seed: u64, k: usize) -> Instance {
    let mut rng = Pcg::seeded(seed);
    let devices = (0..k)
        .map(|_| DeviceInst {
            speed: rng.range_f64(5.0, 200.0),
            offset: if rng.f64() < 0.5 { 0.0 } else { rng.range_f64(0.01, 0.3) },
            b_min: if rng.f64() < 0.5 { 1.0 } else { rng.range_f64(8.0, 32.0) },
            b_max: 128.0,
            rate_ul: rng.range_f64(1e6, 1e8),
            rate_dl: rng.range_f64(1e6, 1e8),
            update_lat: rng.range_f64(0.0, 0.1),
        })
        .collect();
    Instance {
        devices,
        s_bits: rng.range_f64(1e4, 1e7),
        frame_ul: 0.01,
        frame_dl: 0.01,
        xi: rng.range_f64(0.001, 1.0),
    }
}

#[test]
fn prop_solver_always_feasible() {
    // every random instance must yield a feasible, synchronous solution
    for k in [2usize, 5, 13] {
        forall(42, 30, &InstGen { k }, |&(seed, k)| {
            let inst = instance_from(seed, k);
            let Ok(sol) = solve(&inst, 1e-7) else { return false };
            let s = &sol.solution;
            let tau_ok = s.tau_ul.iter().sum::<f64>() <= inst.frame_ul * (1.0 + 1e-5)
                && s.tau_dl.iter().sum::<f64>() <= inst.frame_dl * (1.0 + 1e-5);
            let batch_ok = s
                .batches
                .iter()
                .zip(&inst.devices)
                .all(|(&b, d)| b >= d.b_min - 1e-6 && b <= d.b_max + 1e-6);
            let sync_ok = inst.devices.iter().zip(&s.batches).zip(&s.tau_ul).all(
                |((d, &b), &tau)| {
                    let t = d.offset + b / d.speed
                        + inst.s_bits * inst.frame_ul / (tau * d.rate_ul);
                    t <= s.t_up * (1.0 + 1e-3)
                },
            );
            tau_ok && batch_ok && sync_ok && sol.efficiency > 0.0
        });
    }
}

#[test]
fn prop_uplink_batch_conservation() {
    // sum of allocated batches equals the requested global batch
    forall(7, 40, &PairOf(InstGen { k: 8 }, F64Range(0.1, 0.9)), |((seed, k), frac)| {
        let inst = instance_from(*seed, *k);
        let (lo, hi) = inst.batch_range();
        let b = lo + frac * (hi - lo);
        let Ok(sol) = solve_uplink(&inst, b, 1e-8) else { return false };
        (sol.batches.iter().sum::<f64>() - b).abs() < 1e-2 * b.max(1.0)
    });
}

#[test]
fn prop_efficiency_monotone_in_xi() {
    // scaling xi scales efficiency linearly (same allocation)
    forall(11, 20, &InstGen { k: 6 }, |&(seed, k)| {
        let inst = instance_from(seed, k);
        let mut inst2 = inst.clone();
        inst2.xi *= 3.0;
        let (Ok(a), Ok(b)) = (solve(&inst, 1e-7), solve(&inst2, 1e-7)) else {
            return false;
        };
        (b.efficiency / a.efficiency - 3.0).abs() < 0.05
    });
}

#[test]
fn prop_downlink_slots_positive_and_packed() {
    forall(13, 40, &InstGen { k: 10 }, |&(seed, k)| {
        let inst = instance_from(seed, k);
        let Ok(dl) = solve_downlink(&inst, 1e-9) else { return false };
        let total: f64 = dl.tau.iter().sum();
        dl.tau.iter().all(|&t| t > 0.0)
            && (total - inst.frame_dl).abs() < 1e-4 * inst.frame_dl
    });
}

#[test]
fn prop_partition_always_disjoint_cover() {
    let ds = generate(&SynthConfig { dim: 8, ..Default::default() }, 997, 3);
    forall(17, 25, &PairOf(UsizeRange(1, 16), UsizeRange(0, 1)), |(k, kind)| {
        let kind = if *kind == 0 { Partition::Iid } else { Partition::NonIid };
        let mut rng = Pcg::seeded(*k as u64);
        let parts = partition(&ds, *k, kind, &mut rng);
        let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
        all.sort_unstable();
        all == (0..ds.len()).collect::<Vec<_>>()
    });
}

#[test]
fn prop_quantize_batches_bounds() {
    let inst = instance_from(99, 6);
    forall(19, 50, &VecOf(6, F64Range(1.0, 128.0)), |bs| {
        let q = feel::opt::types::quantize(bs, &inst);
        q.iter()
            .zip(&inst.devices)
            .all(|(&b, d)| b as f64 >= d.b_min - 1e-9 && b as f64 <= d.b_max + 1e-9)
    });
}

#[test]
fn failure_injection_empty_and_degenerate() {
    // degenerate configurations must error, not hang or panic
    let inst = instance_from(1, 4);
    assert!(solve_uplink(&inst, 0.5, 1e-8).is_err()); // below sum b_min
    assert!(solve_uplink(&inst, 1e9, 1e-8).is_err()); // above sum b_max
    let mut bad = inst.clone();
    bad.devices[0].rate_ul = -1.0;
    assert!(bad.validate().is_err());
    let mut bad = inst.clone();
    bad.s_bits = 0.0;
    assert!(bad.validate().is_err());
}

#[test]
fn trainer_full_stack_noniid_vs_iid_gap() {
    // the individual-learning scheme must show a larger IID->non-IID
    // accuracy drop than the proposed scheme (Table II's observation)
    let cfg = SynthConfig { dim: 32, ..Default::default() };
    let train = generate(&cfg, 1200, 5);
    let test = generate(&cfg, 400, 5);
    let run = |scheme: Scheme, part: Partition| -> f64 {
        let be = HostBackend::for_model("mini_res", 32, 10, 1).unwrap();
        let mut rng = Pcg::seeded(9);
        let fleet = paper_cpu_fleet(6, 7e7, 1e8, CellConfig::default(), 4.0, 0.5, &mut rng);
        let tc = TrainerConfig { scheme, eval_every: 0, ..Default::default() };
        let mut tr = Trainer::new(tc, fleet, &train, &test, part, &be).unwrap();
        tr.run(60).unwrap();
        tr.evaluate().unwrap().1
    };
    let gap_prop = run(Scheme::Proposed, Partition::Iid)
        - run(Scheme::Proposed, Partition::NonIid);
    let gap_ind = run(Scheme::Individual { local_batch: 128 }, Partition::Iid)
        - run(Scheme::Individual { local_batch: 128 }, Partition::NonIid);
    assert!(
        gap_ind > gap_prop - 0.02,
        "individual gap {gap_ind} should exceed proposed gap {gap_prop}"
    );
}

#[test]
fn config_to_training_pipeline() {
    // config file -> experiment -> fleet -> one period, end to end
    let src = r#"
model = "mini_mobile"
[fleet]
k = 3
[data]
dim = 16
train_n = 300
test_n = 120
[train]
scheme = "proposed"
eval_every = 1
"#;
    let exp = Experiment::from_config(&Config::parse(src).unwrap()).unwrap();
    let be = HostBackend::for_model(&exp.model, exp.synth.dim, exp.synth.classes, 0).unwrap();
    let train = generate(&exp.synth, exp.train_n, 0);
    let test = generate(&exp.synth, exp.test_n, 0);
    let mut rng = Pcg::seeded(0);
    let fleet = exp.fleet(&mut rng);
    let mut tr =
        Trainer::new(exp.trainer.clone(), fleet, &train, &test, exp.partition, &be).unwrap();
    tr.run(3).unwrap();
    assert_eq!(tr.log.records.len(), 3);
    assert!(tr.log.records[0].test_acc.is_some());
}

#[test]
fn rates_feed_optimizer_sanely() {
    // a real sampled fleet's rates produce a solvable instance every period
    let mut rng = Pcg::seeded(21);
    let mut fleet = paper_cpu_fleet(12, 7e7, 1e8, CellConfig::default(), 8.0, 0.5, &mut rng);
    for _ in 0..50 {
        let rates: Vec<PeriodRates> = fleet.iter_mut().map(|d| d.link.step(&mut rng)).collect();
        let inst =
            Instance::from_fleet(&fleet, &rates, 128.0, 182_400.0, 0.01, 0.01, 0.05).unwrap();
        let sol = solve(&inst, 1e-6).unwrap();
        assert!(sol.efficiency.is_finite() && sol.efficiency > 0.0);
    }
}
